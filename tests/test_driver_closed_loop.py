"""Closed-loop autoscaling driver tests — the ISSUE's acceptance criteria:

* ONE driver loop runs unchanged over both backends (real ElasticServer and
  the discrete-event ServingSimulator),
* the driver scales up on burst backlog and back down after it,
* the engine serves real decode ticks BETWEEN staging increments (>= 3
  mid-stage) with byte-exact TransferStats vs the monolithic path,
* tokens stay divergence-free across the incremental scale event.
"""
import pytest

from helpers import TEST_MOE, run_with_devices


# --------------------------------------------------------------- simulator

def _sim_driver(policy_kw=None, driver_kw=None):
    from repro.configs import get_config
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic")
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=15.0, queue_scale_up=6, confirm_s=1.0,
                           **(policy_kw or {}))
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=15.0,
                                               min_dp=2,
                                               **(driver_kw or {})))
    return mcfg, sim, driver


def test_sim_backend_scales_up_on_burst_and_down_after():
    from repro.serving.workload import burst, make_workload

    mcfg, sim, driver = _sim_driver()
    reqs = make_workload(duration_s=300.0,
                         rps_fn=burst(2.0, 14.0, 60.0, 60.0),
                         prompt_len=2000, output_range=(500, 750), seed=0)
    driver.run(reqs, until=420.0)

    ups = [e for e in driver.events if e.direction == "up"]
    downs = [e for e in driver.events if e.direction == "down"]
    assert ups, "driver never scaled up under the burst"
    assert downs, "driver never scaled back down"
    # scale-up happens during/after burst onset, not before
    assert all(e.t >= 60.0 for e in ups)
    peak = max(ev.new_ndev for ev in sim.events)
    assert peak > 4
    assert sim.ndev < peak, "did not come back down after the burst"
    # the loop kept serving: essentially everything finishes
    assert len(driver.finished) >= 0.95 * len(reqs)


def test_sim_backend_respects_pool_and_cooldown():
    from repro.serving.workload import fixed_rate, make_workload

    mcfg, sim, driver = _sim_driver()
    # hopeless overload: driver must cap at the pool, not beyond
    reqs = make_workload(duration_s=120.0, rps_fn=fixed_rate(80.0),
                         prompt_len=2000, output_range=(500, 750), seed=1)
    driver.run(reqs, until=150.0)
    assert max((ev.new_ndev for ev in sim.events), default=4) <= 8
    assert sim.ndev <= 8
    # decisions are cooldown-spaced
    ts = [e.t for e in driver.events]
    assert all(b - a >= 15.0 - 1e-6 for a, b in zip(ts, ts[1:]))


def test_driver_selects_cost_and_capacity_aware_targets():
    mcfg, sim, driver = _sim_driver()
    # force a backlog so 'up' has demand to cover
    from repro.serving.workload import Request
    for i in range(40):
        sim.submit(Request(i, 0.0, 2000, 600))
    picked = driver.select_target("up")
    assert picked is not None
    tgt, proj = picked
    assert tgt.dp > sim.current_config().dp
    assert tgt.ndev <= 8
    # projected cost comes from the real planner + cost model, with the
    # backend's own settings — it matches what the backend will execute
    assert proj > 0
    assert proj == driver.projected_cost_s(sim.current_config(), tgt)
    task = sim.start_scale(tgt)
    executed = task.event.t_ready - task.event.t_command
    assert abs(executed - proj) < 1e-9, (executed, proj)


def test_driver_disjoint_strategy_targets():
    """extravagant/horizontal provision NEW devices: the driver must build
    disjoint target ranges (not the pool prefix, which overlaps the old
    instance and trips the planner's disjointness assert)."""
    from repro.configs import get_config
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="extravagant")
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=15.0, queue_scale_up=6)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(12),
                           config=DriverConfig(dt=0.05))
    for i in range(40):
        sim.submit(Request(i, 0.0, 2000, 600))
    picked = driver.select_target("up")
    assert picked is not None
    tgt, _ = picked
    assert not set(tgt.devices) & set(sim.current_config().devices)
    sim.start_scale(tgt)                       # planner accepts disjoint set
    # scale-down is not defined for disjoint provisioning
    assert driver.select_target("down") is None


# ------------------------------------------------------------- real engine

@pytest.mark.slow
def test_engine_ticks_between_increments_byte_exact_and_divergence_free():
    """>= 3 real decode ticks land between HMM staging increments; the
    incremental TransferStats equal the monolithic ones field by field; and
    tokens match an unscaled reference exactly."""
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.core.hmm import HMM
from repro.serving.driver import ScalePhase
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

# monolithic reference byte accounting (no serving, boot only)
href = HMM(MCFG, tp=2, batch_per_replica=2, max_len=128, seed=0)
href.boot(c4)
ref_stats = href.scale(c6)

def run(scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0)
    srv.boot(c4 if scale else c6)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 40, prompt=rng.integers(0,128,16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n, task, mid_ticks = 0.0, 0, None, 0
    while any(r.finish_s is None for r in reqs):
        if scale and n == 5 and task is None:
            task = srv.start_scale(c6)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            if task.phase is ScalePhase.STAGING:
                mid_ticks += 1          # this tick ran between increments
            task.advance(t)
        assert n < 500
    toks = {r.rid: srv.engine.generated[r.rid] for r in reqs}
    return toks, task, mid_ticks

ref_toks, _, _ = run(False)
got_toks, task, mid_ticks = run(True)
assert task is not None and task.phase is ScalePhase.DONE
assert mid_ticks >= 3, mid_ticks
for f in ("zero_copy_bytes", "p2p_bytes", "local_bytes", "init_bytes",
          "zero_copy_count", "p2p_count"):
    a, b = getattr(ref_stats, f), getattr(task.stage_stats, f)
    assert a == b, (f, a, b)
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], (rid, ref_toks[rid], got_toks[rid])
print(f"INTERLEAVE-OK ticks={mid_ticks} zc={task.stage_stats.zero_copy_bytes}")
""")
    assert "INTERLEAVE-OK" in out


@pytest.mark.slow
def test_engine_backend_closed_loop_up_then_down():
    """The SAME ClusterDriver loop used on the simulator drives the real
    engine: backlog -> scale up (serving mid-stage), idle -> drain + scale
    down, everything finishes."""
    out = run_with_devices(TEST_MOE + """
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO
from repro.serving.workload import scripted_burst

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
policy = ScalingPolicy(slo=SLO(ttft_s=1.0, tpot_s=1.0), window=8,
                       cooldown_s=1.0, queue_scale_up=3)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0)
srv.boot(c4)
srv.preinitialize(c6)
driver = ClusterDriver(srv, policy, mcfg=MCFG, tp=2, device_pool=range(6),
                       config=DriverConfig(dt=0.05, settle_s=2.0,
                                           prewarm_next=False))
reqs = scripted_burst([(0.0, 2), (0.5, 7), (6.0, 1)], vocab_size=128, seed=1)
until = 0.0
while any(r.finish_s is None for r in reqs):
    until += 10.0
    driver.run(reqs if until == 10.0 else [], until=until)
    assert until < 200.0, "stalled"
dirs = [e.direction for e in driver.events]
assert "up" in dirs, dirs
assert "down" in dirs, dirs
assert srv.hmm.active_cfg.ndev == 4, srv.hmm.active_cfg
assert srv.engine.num_slots == 4
# every executed event staged + switched with bytes moved or reused
for ev in srv.events:
    assert ev.stats.zero_copy_bytes > 0
print("CLOSED-LOOP-OK", dirs)
""")
    assert "CLOSED-LOOP-OK" in out
