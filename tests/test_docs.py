"""Docs lint as a tier-1 test: the documentation suite exists and every
``*.md`` file cited from a docstring resolves (same check CI runs via
tools/check_doc_refs.py)."""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_required_docs_exist():
    for doc in ("README.md", "DESIGN.md", "EXPERIMENTS.md",
                "benchmarks/README.md", "ROADMAP.md"):
        assert (REPO / doc).is_file(), f"missing {doc}"


def test_design_md_has_cited_sections():
    """Docstrings cite DESIGN.md §2/§4/§5 and EXPERIMENTS.md §Perf B —
    the anchors must exist, not just the files."""
    design = (REPO / "DESIGN.md").read_text()
    for anchor in ("## §1", "## §2", "## §3", "## §4", "## §5", "## §6"):
        assert anchor in design, f"DESIGN.md lost section {anchor!r}"
    experiments = (REPO / "EXPERIMENTS.md").read_text()
    for anchor in ("§Perf iteration 0", "§Perf iteration A",
                   "§Perf B", "§Dry-run", "§Roofline"):
        assert anchor in experiments, f"EXPERIMENTS.md lost {anchor!r}"


def test_no_dangling_md_references():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        from check_doc_refs import dangling_refs
    finally:
        sys.path.pop(0)
    missing = dangling_refs(REPO)
    assert not missing, f"dangling .md references: {missing}"
