"""Property tests on MoE routing/dispatch (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.moe import _dispatch_indices, capacity_for, route


@settings(max_examples=30, deadline=None)
@given(T=st.integers(4, 64), E=st.sampled_from([4, 8, 16]),
       k=st.integers(1, 3), C=st.integers(1, 16), seed=st.integers(0, 10))
def test_dispatch_slots_unique_and_capped(T, E, k, C, seed):
    rng = np.random.default_rng(seed)
    topk = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    expert_flat, slot, keep = map(np.asarray, _dispatch_indices(topk, E, C))
    # kept entries occupy unique (expert, slot) pairs, slots < C
    pairs = [(e, s) for e, s, kp in zip(expert_flat, slot, keep) if kp]
    assert len(pairs) == len(set(pairs))
    assert all(s < C for _, s in pairs)
    # dropped entries are exactly those past capacity, in order
    for e in range(E):
        entries = [i for i, ee in enumerate(expert_flat) if ee == e]
        kept = [i for i in entries if keep[i]]
        assert len(kept) == min(len(entries), C)
        assert kept == entries[:len(kept)]


@settings(max_examples=20, deadline=None)
@given(T=st.integers(2, 32), seed=st.integers(0, 5))
def test_router_weights_normalized(T, seed):
    E, k, D = 8, 2, 16
    p = {"w": jax.random.normal(jax.random.PRNGKey(seed), (D, E))}
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, D))
    idx, w, aux = route(p, x, k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert idx.shape == (T, k)
    assert float(aux) >= 0.99  # load-balance loss >= 1 at optimum


def test_moe_local_dropless_equals_dense_mixture():
    """With dropless capacity, moe_local == explicit top-k mixture."""
    from repro.configs.base import ModelConfig
    from repro.models.moe import moe_init, moe_local
    cfg = ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                      vocab_size=64, num_heads=2, num_kv_heads=2,
                      num_experts=4, top_k=2, moe_d_ff=16, dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    T = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model))
    y, _ = moe_local(cfg, p, x, capacity=T * cfg.top_k)

    idx, w, _ = route(p["router"], x, cfg.top_k)
    want = jnp.zeros_like(x)
    for t in range(T):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = x[t] @ p["wi"][e]
            g = jax.nn.silu(x[t] @ p["wg"][e])
            want = want.at[t].add(w[t, j] * ((h * g) @ p["wo"][e]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
