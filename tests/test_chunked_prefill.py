"""Continuous batching with chunked prefill (real JAX, subprocess):

* bit-identical-token parity, chunked vs monolithic, over the full
  (dense | pooled experts) x (dense | paged KV) matrix — including a
  request whose prefill token is its only token,
* prefix-cache-aware admission under chunked deferred registration:
  staggered shared-prefix arrivals still share blocks, tokens still match
  the monolithic run bit for bit,
* a scale-up committing while prompts are mid-chunk: jobs keep chunking
  through the staging window and every token matches the unscaled run,
* a migrate-mode scale-down landing mid-chunk: jobs in doomed slots pause
  while their blocks move, resume re-homed on survivors, no recompute,
* recompute-preemption under pool pressure with chunked admission.

Mirrors the PR 4/5 determinism-matrix idiom (tests/test_paged_engine.py,
tests/test_scaledown_migration.py).
"""
import pytest

from helpers import TEST_MOE, run_with_devices

CHUNK_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request, shared_prefix_workload

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def build(kv_mode, expert_mode="dense", chunk=0, budget=None, boot=c4, **kw):
    kw.setdefault("prefill_buckets", (32, 64, 96))
    kw.setdefault("batch_per_replica", 4)
    kw.setdefault("max_len", 128)
    srv = ElasticServer(MCFG, tp=2,
                        seed=0, kv_mode=kv_mode, kv_block_size=16,
                        expert_mode=expert_mode, prefill_chunk=chunk,
                        prefill_budget=budget, **kw)
    if boot is not None:
        srv.boot(boot)
    return srv

def drive(srv, reqs, tmax=3000):
    pending = sorted(reqs, key=lambda r: r.arrival_s)
    t, n, i = 0.0, 0, 0
    while any(r.finish_s is None for r in reqs):
        while i < len(pending) and pending[i].arrival_s <= t:
            srv.submit(pending[i]); i += 1
        srv.tick(t); t += .1; n += 1
        assert n < tmax, [r.finish_s for r in reqs]
    return srv

def mixed_reqs(seed=0):
    # prompt lengths straddle chunk (32) and block (16) boundaries; rid 3
    # has output_len 1 (its first token is its only token — the
    # finished-at-prefill path must still report completion)
    rng = np.random.default_rng(seed)
    lens = [10, 37, 90, 16, 64, 45]
    outs = [8, 12, 16, 1, 10, 6]
    return [Request(i, 0.2 * i, L, o, prompt=rng.integers(0, 128, L))
            for i, (L, o) in enumerate(zip(lens, outs))]
"""


@pytest.mark.slow
def test_chunked_matches_monolithic_matrix():
    """Chunked prefill must be a pure scheduling change: for every
    (expert layout) x (KV layout) combination the generated tokens equal
    the monolithic engine's bit for bit."""
    out = run_with_devices(CHUNK_COMMON + """
for kv in ("dense", "paged"):
    for em in ("dense", "pooled"):
        mono = drive(build(kv, em), mixed_reqs())
        chnk = drive(build(kv, em, chunk=32, budget=64), mixed_reqs())
        assert set(mono.engine.generated) == set(chnk.engine.generated)
        for rid in mono.engine.generated:
            assert mono.engine.generated[rid] == chnk.engine.generated[rid], \
                (kv, em, rid)
        assert len(chnk.engine.generated[3]) == 1      # output_len-1 request
        if kv == "paged":
            assert chnk.engine.kv_stats()["used_blocks"] == 0
            chnk.hmm.kv_blocks.check_invariants()
        print(f"CHUNK-MATRIX-{kv}-{em}-OK")
print("CHUNK-PARITY-MATRIX-OK")
""", ndev=4)
    for kv in ("dense", "paged"):
        for em in ("dense", "pooled"):
            assert f"CHUNK-MATRIX-{kv}-{em}-OK" in out
    assert "CHUNK-PARITY-MATRIX-OK" in out


@pytest.mark.slow
def test_chunked_prefix_sharing_parity():
    """Deferred registration: arrivals staggered across ticks still bind to
    the partition holding their written prefix (shared_block_hits > 0) and
    the skipped-prefix prefill produces tokens identical to the monolithic
    engine that recomputes over sentinel rows."""
    out = run_with_devices(CHUNK_COMMON + """
# one arrival every 4 ticks: each prompt's prefix blocks are fully written
# (registered) before the next arrival queries the registry — same-tick
# arrivals must NOT share (their blocks hold no data yet)
reqs = lambda: shared_prefix_workload(
    [(0.0, 1), (0.4, 1), (0.8, 1), (1.2, 1), (1.6, 1)], prefix_len=40,
    suffix_range=(0, 6), vocab_size=128, seed=2, output_range=(10, 20))

mono = drive(build("paged"), reqs())
chnk = drive(build("paged", chunk=32, budget=32), reqs())
st = chnk.engine.kv_stats()
assert st["shared_block_hits"] > 0, st
assert st["used_blocks"] == 0, st
chnk.hmm.kv_blocks.check_invariants()
for rid in mono.engine.generated:
    assert mono.engine.generated[rid] == chnk.engine.generated[rid], rid
print("CHUNK-PREFIX-SHARING-OK", st["shared_block_hits"])
""", ndev=4)
    assert "CHUNK-PREFIX-SHARING-OK" in out


@pytest.mark.slow
def test_chunked_tokens_identical_across_scaleup():
    """Scale 4->6 devices while long prompts are mid-chunk: jobs keep
    chunking through the staging window (no pause on scale-up), survive the
    switchover rebind verbatim, and every token matches a run that started
    on the target config."""
    out = run_with_devices(CHUNK_COMMON + """
def run(scale):
    srv = build("paged", chunk=32, budget=32, prefill_buckets=(32,),
                batch_per_replica=2, boot=c4 if scale else c6)
    rng = np.random.default_rng(0)
    lens = [16, 90, 90, 37]
    reqs = [Request(i, 0.0, L, 30, prompt=rng.integers(0, 128, L))
            for i, L in enumerate(lens)]
    for r in reqs: srv.submit(r)
    t, n, task, overlapped = 0.0, 0, None, False
    while any(r.finish_s is None for r in reqs):
        if scale and n == 1 and task is None:
            assert any(s.prefilling for s in srv.engine.slots if s.rid >= 0)
            task = srv.start_scale(c6)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
            if srv.engine._prefilling:
                overlapped = True
        assert n < 800, [r.finish_s for r in reqs]
    if scale:
        assert overlapped, "no prefill job was in flight during the scale"
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv

ref_toks, _ = run(False)
got_toks, srv = run(True)
assert srv.hmm.kv_blocks.num_partitions == 3
assert srv.engine.preemptions == 0
srv.hmm.kv_blocks.check_invariants()
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], rid
print("CHUNK-SCALEUP-DETERMINISM-OK")
""")
    assert "CHUNK-SCALEUP-DETERMINISM-OK" in out


@pytest.mark.slow
def test_chunked_migrate_scaledown_lands_mid_chunk():
    """Migrate-mode scale-down 6->4 with prompts still chunking in the
    doomed partition: their jobs pause while blocks move (no chunk writes
    into frozen blocks), resume re-homed on survivor slots, nothing is
    recomputed, and tokens match the unscaled run at the target config."""
    out = run_with_devices(CHUNK_COMMON + """
from repro.serving.driver import ScalePhase

def run(scale):
    # chunk=16 (one block) with budget=16: the FIFO backlog drains one
    # chunk per tick, so the doomed 200-token prompts stay mid-prefill
    # well past the staging window into MIGRATING (~tick 16)
    srv = build("paged", chunk=16, budget=16, prefill_buckets=(32,),
                batch_per_replica=2, max_len=256, boot=c6 if scale else c4)
    assert srv.scaledown_mode == "migrate"
    rng = np.random.default_rng(0)
    # rids 0-1: short, free their survivor slots early; rids 4-5: long
    # prompts landing in the doomed partition, mid-chunk at scale time
    lens = [10, 10, 16, 16, 200, 200]
    outs = [2, 2, 30, 30, 30, 30]
    reqs = [Request(i, 0.0, L, o, prompt=rng.integers(0, 128, L))
            for i, (L, o) in enumerate(zip(lens, outs))]
    for r in reqs: srv.submit(r)
    t, n, task, paused_mid_chunk = 0.0, 0, None, False
    while any(r.finish_s is None for r in reqs):
        if scale and n == 1 and task is None:
            task = srv.start_scale(c4)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
            if any(s.prefilling and s.migrating for s in srv.engine.slots):
                paused_mid_chunk = True
        assert n < 2000, [r.finish_s for r in reqs]
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv, task, \
        paused_mid_chunk

ref_toks, _, _, _ = run(False)
got_toks, srv, task, paused = run(True)
assert srv.hmm.active_cfg.ndev == 4
assert srv.hmm.kv_blocks.num_partitions == 2
assert paused, "no prefill job was paused by a live migration"
assert task.migrated_blocks > 0
assert srv.engine.preemptions == 0              # migrated, never recomputed
assert srv.engine.kv_stats()["used_blocks"] == 0
srv.hmm.kv_blocks.check_invariants()
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], rid
print("CHUNK-MIGRATE-MID-CHUNK-OK", task.migrated_blocks)
""")
    assert "CHUNK-MIGRATE-MID-CHUNK-OK" in out


@pytest.mark.slow
def test_chunked_preempts_under_pressure_and_completes():
    """Chunked admission holds a prompt's blocks from allocation: under an
    over-committed pool the engine still preempts (recompute) rather than
    deadlocking, resumed requests re-chunk prompt+generated, and the pool
    drains clean."""
    out = run_with_devices(CHUNK_COMMON + """
srv = build("paged", chunk=32, budget=32, prefill_buckets=(32,),
            kv_blocks_per_replica=8)
rng = np.random.default_rng(1)
reqs = [Request(i, 0.0, 16, 60, prompt=rng.integers(0, 128, 16))
        for i in range(8)]
drive(srv, reqs)
assert srv.engine.preemptions > 0
assert srv.engine.kv_stats()["used_blocks"] == 0
srv.hmm.kv_blocks.check_invariants()
for r in reqs:
    assert len(srv.engine.generated[r.rid]) == r.output_len, r.rid
print("CHUNK-PREEMPT-OK", srv.engine.preemptions)
""", ndev=4)
    assert "CHUNK-PREEMPT-OK" in out
