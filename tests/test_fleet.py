"""Fleet refactor acceptance (DESIGN.md §12): one shared accelerator pool,
many models, scale-to-zero via the whole-model pinned-host tier.

* ``DevicePool`` allocator contracts: overlapping ids / double-booking
  raise at construction or claim time; ``check_invariants`` cross-checks
  the allocator against a per-model lease ledger.
* ``IMM`` standby keys carry the full model identity, so two fleet models
  on the same mesh can share one LRU without colliding.
* ``unpark_transition_cost`` pricing sanity (cold start at ``h2d_bw``;
  ``preinit=False`` adds the cold-boot tail; serial >= overlap).
* Simulator park/unpark semantics (queue accrues at ndev=0; the unpark
  task drains it; ``park_events`` records the cold-start wall).
* Hypothesis property suite: random per-model demand traces through the
  ``FleetDriver`` — device conservation every tick, ``min_devices``
  floors respected, and a parked model's next request always unparks it
  (every request finishes).
* Slow tier: engine-level park -> unpark round trip is byte-exact
  (bit-identical tokens vs an unscaled run) and the exported Chrome
  trace shows the unpark H2D window hiding the AOT compile.

CI runs the hypothesis tests under the fixed profile registered below
(deadline disabled, derandomized) so they cannot flake.
"""
import os

import pytest

from helpers import TEST_MOE, run_with_devices

try:                                   # optional test extra: the property
    from hypothesis import given, settings   # tests fall back to fixed
    from hypothesis import strategies as st  # representative cases
    HAVE_HYPOTHESIS = True
    settings.register_profile("repro-ci", deadline=None, derandomize=True,
                              max_examples=40)
    settings.register_profile("repro-ci-thorough", deadline=None,
                              derandomize=True, max_examples=300)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro-ci"))
except ImportError:
    HAVE_HYPOTHESIS = False


def _given_or_cases(cases, **strategies):
    """``@given(**strategies)`` when hypothesis is installed; otherwise
    parametrize over the fixed ``cases`` so the properties still execute
    (deterministically) on minimal environments."""
    if HAVE_HYPOTHESIS:
        return given(**strategies)
    return pytest.mark.parametrize(",".join(strategies), cases)


MODEL = "deepseek-v2-lite-16b"


def _mk_sim(ndev, **kw):
    from repro.configs import get_config
    from repro.serving.simulator import ServingSimulator
    return ServingSimulator(get_config(MODEL), tp=2, ndev=ndev,
                            staging="overlap", **kw)


def _policy(**kw):
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.metrics import SLO
    base = dict(slo=SLO(ttft_s=10.0, tpot_s=1.5), window=8, cooldown_s=5.0,
                queue_scale_up=3, confirm_s=0.5, idle_utilization=0.4)
    base.update(kw)
    return ScalingPolicy(**base)


# ------------------------------------------------------- DevicePool allocator

def test_device_pool_rejects_overlapping_ids():
    import repro.core  # noqa: F401 — break the serving.driver import cycle
    from repro.serving.driver import DevicePool
    with pytest.raises(ValueError, match="duplicate"):
        DevicePool([0, 1, 1])


def test_device_pool_claim_release_contracts():
    import repro.core  # noqa: F401
    from repro.serving.driver import DevicePool
    p = DevicePool(range(4))
    assert p.claim("a", [0, 1]) == (0, 1)
    with pytest.raises(ValueError, match="already owned"):
        p.claim("b", [1])                 # double-booking across owners
    with pytest.raises(ValueError, match="already owned"):
        p.claim("a", [0])                 # double-claim by the SAME owner
    with pytest.raises(ValueError, match="not in the pool"):
        p.claim("b", [9])
    with pytest.raises(ValueError, match="duplicate"):
        p.claim("b", [2, 2])
    with pytest.raises(ValueError, match="refusing the release"):
        p.release("b", [0])               # not the owner
    with pytest.raises(ValueError, match="refusing the release"):
        p.release("a", [2])               # free device
    p.release("a", [0])
    assert p.claim("b", [0]) == (0,)      # released devices recirculate
    assert set(p.free()) == {2, 3}
    assert p.owned("a") == (1,) and p.owned("b") == (0,)


def test_device_pool_invariants_cross_check_ledger():
    import repro.core  # noqa: F401
    from repro.serving.driver import DevicePool
    p = DevicePool(range(4))
    p.claim("a", [0, 1])
    p.claim("b", [2])
    p.check_invariants()
    p.check_invariants({"a": [0, 1], "b": [2]})
    with pytest.raises(AssertionError):
        p.check_invariants({"a": [0, 1]})           # b's lease leaked
    with pytest.raises(AssertionError):
        p.check_invariants({"a": [0, 1], "b": [3]})  # ledger disagrees
    with pytest.raises(AssertionError):
        p.check_invariants({"a": [0, 1, 2], "b": [2]})  # double-leased


def test_two_cluster_drivers_cannot_share_a_pool():
    """The satellite's construction-time guard: a second driver booting on
    an already-claimed pool raises instead of double-booking devices."""
    import repro.core  # noqa: F401 — break the serving.driver import cycle
    from repro.configs import get_config
    from repro.serving.driver import ClusterDriver, DevicePool, DriverConfig
    pool = DevicePool(range(8))
    mcfg = get_config(MODEL)
    ClusterDriver(_mk_sim(4), _policy(), mcfg=mcfg, tp=2, device_pool=pool,
                  config=DriverConfig(dt=0.05))
    with pytest.raises(ValueError, match="already owned"):
        ClusterDriver(_mk_sim(4), _policy(), mcfg=mcfg, tp=2,
                      device_pool=pool, config=DriverConfig(dt=0.05))


def test_fleet_boot_overflow_and_duplicate_names_raise():
    from repro.serving.fleet import FleetDriver, FleetModelSpec
    from repro.configs import get_config
    mcfg = get_config(MODEL)

    def spec(name, ndev):
        return FleetModelSpec(name=name, backend=_mk_sim(ndev),
                              policy=_policy(), mcfg=mcfg, tp=2)
    with pytest.raises(ValueError, match="already owned|cannot cover"):
        FleetDriver([spec("a", 4), spec("b", 4)], range(6))
    with pytest.raises(AssertionError, match="duplicate model names"):
        FleetDriver([spec("a", 2), spec("a", 2)], range(8))


# ------------------------------------------------- IMM standby key separation

def test_imm_standby_key_carries_model_identity():
    """Two fleet models with the SAME (dp, tp, devices) mesh must never
    collide in a shared standby LRU — the key carries the model config and
    every compile-affecting knob."""
    import types
    from collections import OrderedDict

    import repro.core  # noqa: F401
    from repro.configs import get_config
    from repro.core.imm import IMM
    from repro.core.topology import ElasticConfig

    def hmm_attrs():
        return types.SimpleNamespace(
            kv_mode="paged", kv_block_size=16, kv_blocks_per_replica=64,
            expert_mode="pooled", expert_pool_pages=0, expert_slot_slack=0,
            kv_dtype=None, expert_dtype=None)

    shared = OrderedDict()
    a = IMM(get_config(MODEL), hmm_attrs(), batch_per_replica=4, max_len=128,
            shared_cache=shared)
    b = IMM(get_config("qwen3-30b-a3b"), hmm_attrs(), batch_per_replica=4,
            max_len=128, shared_cache=shared)
    cfg = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
    assert a._key(cfg) != b._key(cfg)
    assert a._cache is b._cache           # one LRU, one capacity bound
    shared[a._key(cfg)] = "standby-a"     # simulate a's compiled standby
    assert a.has(cfg) and not b.has(cfg)
    # same model, different layout knob -> also a different key
    c_attrs = hmm_attrs()
    c_attrs.kv_block_size = 32
    c = IMM(get_config(MODEL), c_attrs, batch_per_replica=4, max_len=128,
            shared_cache=shared)
    assert not c.has(cfg)


# ------------------------------------------------------- cold-start pricing

def test_unpark_transition_cost_pricing():
    import repro.core  # noqa: F401
    from repro.configs import get_config
    from repro.core.topology import ElasticConfig
    from repro.serving.driver import unpark_transition_cost

    mcfg = get_config(MODEL)
    tgt = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
    warm = unpark_transition_cost(mcfg, 2, tgt)
    assert warm.scale_time_s > 0
    assert warm.downtime_s == warm.scale_time_s  # parked => all dead time
    assert "cold_start" in warm.breakdown
    cold = unpark_transition_cost(mcfg, 2, tgt, preinit=False)
    assert cold.scale_time_s > warm.scale_time_s  # cold-boot serial tail
    serial = unpark_transition_cost(mcfg, 2, tgt, staging="serial")
    assert serial.scale_time_s >= warm.scale_time_s  # overlap hides H2D


# ------------------------------------------------- simulator park/unpark

def test_sim_park_unpark_queue_accrual_and_cold_start_wall():
    from repro.core.topology import ElasticConfig
    from repro.serving.workload import Request

    sim = _mk_sim(4)
    sim.run([Request(0, 0.0, 2000, 20)], until=30.0)
    assert sim.finished and sim.finished[0].finish_s is not None
    sim.park()
    assert sim.parked and sim.ndev == 0
    assert sim.park_events[-1]["kind"] == "park"
    # parked: submissions accrue, nothing serves
    sim.submit(Request(1, sim.t, 2000, 20))
    t0 = sim.t
    for _ in range(10):
        sim.step(sim.t + 0.05)
    assert sim.queue_depth() == 1 and sim.utilization() == 0.0
    with pytest.raises(AssertionError):
        sim.park()                        # double-park is a bookkeeping bug
    task = sim.start_unpark(ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3)))
    ev = sim.park_events[-1]
    assert ev["kind"] == "unpark" and ev["wall_s"] > 0
    until = sim.t + ev["wall_s"] + 60.0
    while sim.t < until and sim.queue_depth() + len(sim.running):
        task.advance(sim.t)
        sim.step(sim.t + 0.05)
    assert task.done and not sim.parked and sim.ndev == 4
    r = sim.finished[-1]
    assert r.rid == 1 and r.finish_s is not None
    # the cold-start wall is dead time for the queued request
    assert r.ttft >= ev["wall_s"] - 1e-6, (r.ttft, ev["wall_s"])
    assert t0 + ev["wall_s"] <= r.first_token_s


# --------------------------------------------------- fleet driver properties

def _arrivals(windows, window_s, prompt_len=2000, output_len=24):
    """Deterministic arrival stream: ``windows`` are per-window request
    rates; requests are evenly spaced inside each window."""
    from repro.serving.workload import Request
    reqs, rid = [], 0
    for i, rate in enumerate(windows):
        n = int(rate * window_s)
        for k in range(n):
            reqs.append(Request(rid, i * window_s + (k + 0.5) * window_s / n,
                                prompt_len, output_len))
            rid += 1
    return reqs


def _drive(fd, arrivals, cap_s=600.0):
    """Run the fleet loop (conservation is checked every tick inside) until
    every request finishes, extending in 30s slabs up to ``cap_s``."""
    until, first = 30.0, True
    total = sum(len(v) for v in arrivals.values())
    while True:
        res = fd.run(arrivals if first else {}, until=until)
        first = False
        done = sum(len(v) for v in res.values())
        if done == total:
            return res
        assert until < cap_s, \
            f"fleet stalled: {done}/{total} finished by t={until}"
        until += 30.0


def test_fleet_parks_idle_model_and_unparks_on_next_request():
    """Deterministic scale-to-zero round trip through the driver: an idle
    trough parks the model (lease -> 0, devices back to the pool); the next
    queued request triggers the unpark and gets served."""
    from repro.configs import get_config
    from repro.serving.fleet import FleetConfig, FleetDriver, FleetModelSpec

    spec = FleetModelSpec(name="solo", backend=_mk_sim(2), policy=_policy(),
                          mcfg=get_config(MODEL), tp=2, min_devices=0,
                          park_after_idle_s=5.0)
    fd = FleetDriver([spec], range(4),
                     FleetConfig(dt=0.1, settle_s=2.0, sample_every_s=2.0))
    from repro.serving.workload import Request
    reqs = _arrivals([2.0], 10.0)         # 20 requests in [0, 10)
    late = [Request(100, 60.0, 2000, 24)]  # arrives well after the park
    res = _drive(fd, {"solo": reqs + late})
    kinds = [e.kind for e in fd.events]
    assert "park" in kinds and "unpark" in kinds
    assert kinds.index("park") < kinds.index("unpark")
    assert len(res["solo"]) == 21
    # while parked the model held nothing and the pool saw every device
    parked_t = next(e.t for e in fd.events if e.kind == "park")
    unparked_t = next(e.t for e in fd.events if e.kind == "unpark")
    for row in fd.timeline:
        if parked_t < row["t"] < unparked_t:
            assert row["solo"] == 0 and row["free"] == 4
    fd.check_invariants()


@_given_or_cases(
    [([0.0, 1.0, 0.0], [3.0, 0.0, 5.0], 0),
     ([1.0, 3.0, 0.0], [0.0, 5.0, 1.0], 4),
     ([0.0, 0.0, 3.0], [5.0, 3.0, 0.0], 4)],
    windows_a=st.lists(st.sampled_from([0.0, 0.0, 1.0, 3.0]),
                       min_size=3, max_size=3) if HAVE_HYPOTHESIS else None,
    windows_b=st.lists(st.sampled_from([0.0, 1.0, 3.0, 5.0]),
                       min_size=3, max_size=3) if HAVE_HYPOTHESIS else None,
    floor_b=st.sampled_from([0, 4]) if HAVE_HYPOTHESIS else None)
def test_fleet_random_demand_conserves_devices_and_floors(windows_a,
                                                          windows_b,
                                                          floor_b):
    """Random per-model demand traces through the allocator: device
    conservation holds every tick (``check_invariants`` runs inside the
    loop), ``min_devices`` floors are never violated, parked models with
    queued requests always unpark (every request finishes)."""
    from repro.configs import get_config
    from repro.serving.fleet import FleetConfig, FleetDriver, FleetModelSpec

    mcfg = get_config(MODEL)
    boot_b = max(floor_b, 2)
    specs = [
        FleetModelSpec(name="a", backend=_mk_sim(2), policy=_policy(),
                       mcfg=mcfg, tp=2, min_devices=0,
                       park_after_idle_s=8.0),
        FleetModelSpec(name="b", backend=_mk_sim(boot_b), policy=_policy(),
                       mcfg=mcfg, tp=2, min_devices=floor_b,
                       park_after_idle_s=8.0),
    ]
    fd = FleetDriver(specs, range(10),
                     FleetConfig(dt=0.1, settle_s=3.0, max_step_dp=2,
                                 sample_every_s=5.0))
    arrivals = {"a": _arrivals(windows_a, 25.0),
                "b": _arrivals(windows_b, 25.0)}
    res = _drive(fd, arrivals)
    # every request finished => queued requests on parked models unparked
    assert sorted(len(v) for v in res.values()) == \
        sorted(len(v) for v in arrivals.values())
    fd.check_invariants()
    leases = {n: st_.lease for n, st_ in fd.states.items()}
    assert sum(map(len, leases.values())) + len(fd.pool.free()) == 10
    # min_devices floor: the floored model never parked and never sampled
    # below its floor; scale-downs never targeted a sub-floor config
    if floor_b > 0:
        assert not any(e.kind == "park" and e.model == "b"
                       for e in fd.events)
        assert all(row["b"] >= floor_b for row in fd.timeline)
        assert len(leases["b"]) >= floor_b
    for e in fd.events:
        if e.kind == "down":              # dst like "DP2-TP2-EP4@[...]"
            spec = fd.states[e.model].spec
            dst_dp = int(e.dst.split("DP")[1].split("-")[0])
            assert dst_dp >= fd._min_dp(spec)


@_given_or_cases(
    [(20.0, 1), (35.0, 2), (50.0, 4)],
    gap=st.sampled_from([20.0, 35.0, 50.0]) if HAVE_HYPOTHESIS else None,
    late_n=st.integers(1, 4) if HAVE_HYPOTHESIS else None)
def test_fleet_parked_model_next_request_always_unparks(gap, late_n):
    """The scale-to-zero liveness property, directly: whatever the idle gap
    and the size of the late batch, a parked model's queued requests pull
    it back through an unpark and all finish."""
    from repro.configs import get_config
    from repro.serving.fleet import FleetConfig, FleetDriver, FleetModelSpec
    from repro.serving.workload import Request

    spec = FleetModelSpec(name="m", backend=_mk_sim(2), policy=_policy(),
                          mcfg=get_config(MODEL), tp=2, min_devices=0,
                          park_after_idle_s=6.0)
    fd = FleetDriver([spec], range(4),
                     FleetConfig(dt=0.1, settle_s=2.0))
    reqs = _arrivals([1.0], 8.0)
    reqs += [Request(1000 + i, 8.0 + gap + 0.1 * i, 2000, 24)
             for i in range(late_n)]
    res = _drive(fd, {"m": reqs})
    assert len(res["m"]) == len(reqs)
    kinds = [e.kind for e in fd.events]
    if "park" in kinds:                   # gap long enough to park
        assert "unpark" in kinds[kinds.index("park"):]


# ------------------------------------------------------- slow tier (engine)

@pytest.mark.slow
def test_engine_park_unpark_byte_exact_with_trace_overlap(tmp_path):
    """ISSUE acceptance: park -> unpark round-trips byte-exact (bit-identical
    tokens vs an unscaled run) and the exported trace shows the unpark H2D
    transfer window overlapping the IMM AOT compile (STAGING ∥ COMPILING)."""
    trace_path = tmp_path / "trace.json"
    out = run_with_devices(TEST_MOE + f"""
import time
import numpy as np
from repro import obs
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.workload import Request

tr = obs.install(obs.Tracer(capacity=200_000))

def reqs():
    rng = np.random.default_rng(0)
    return [Request(rid=i, arrival_s=0.0,
                    prompt=rng.integers(1, 100, size=12).tolist(),
                    prompt_len=12, output_len=8) for i in range(3)]

def serve(server):
    out = {{}}
    for r in reqs():
        server.submit(r)
    t = 0.0
    while len(out) < 3 and t < 200:
        for rid in server.tick(t):
            out[rid] = list(server.engine.generated[rid])
        t += 0.05
    return out

cfg = ElasticConfig(dp=1, tp=2, devices=(0, 1))
kw = dict(tp=2, batch_per_replica=4, max_len=32, prefill_buckets=(16,),
          kv_mode="paged", kv_block_size=4, expert_mode="pooled",
          staging="overlap", seed=0, transfer_workers=1)

ref = ElasticServer(MCFG, **kw)
ref.boot(cfg)
base = serve(ref)

srv = ElasticServer(MCFG, **kw)
srv.boot(cfg)
_ = serve(srv)                       # warm, then drain -> park
st = srv.park()
assert srv.parked and srv.current_config() is None
assert srv.utilization() == 0.0 and srv.tick(0.0) == []
assert st.d2h_bytes > 0 and srv.hmm.parked_bytes() == st.d2h_bytes

# force a REAL AOT compile during the unpark (a standby hit would make the
# compile span ~0s) and throttle each H2D op so the transfer window
# deterministically spans it (same trick as test_trace_overlap.py)
srv.imm._cache.clear()
orig = srv.hmm._stage_unit
def slow_unit(*a, **k):
    time.sleep(0.05)
    return orig(*a, **k)
srv.hmm._stage_unit = slow_unit

task = srv.start_unpark(cfg)
t = 500.0
while not task.done:
    task.advance(t)
    srv.tick(t)                      # legal (and a no-op) mid-unpark
    t += 0.05
srv.hmm._stage_unit = orig
assert not srv.parked and task.event.compile_hit is False
assert task.stats.h2d_bytes > 0

out2 = serve(srv)
assert out2 == base, (out2, base)
print("byte-exact tokens after park->unpark OK")

doc = obs.write_chrome_trace({str(trace_path)!r}, tr)
obs.validate_trace(doc)
spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
h2d = [e for e in spans if str(e["name"]).startswith("unpark:")]
comp = [e for e in spans if e["name"] == "unpark.compile"]
assert h2d, "no unpark TransferOp spans in trace"
assert comp, "no unpark.compile span in trace"

def overlap(a, b):
    return max(a["ts"], b["ts"]) < min(a["ts"] + a["dur"],
                                       b["ts"] + b["dur"])

assert any(overlap(a, b) for a in h2d for b in comp), \\
    "unpark H2D transfer did not overlap the AOT compile"
print("unpark transfer overlapped AOT compile in exported trace OK")
""", ndev=2, timeout=600)
    assert "byte-exact tokens after park->unpark OK" in out
    assert "overlapped AOT compile in exported trace OK" in out
