"""Fleet serving A/B (DESIGN.md §12): one shared pool + scale-to-zero vs
static per-model pools on anti-correlated diurnal + burst demand.

Three logical models (same architecture, independent traffic) ride
staggered diurnal rate curves with a burst at each model's own crest —
the regime the fleet refactor targets: aggregate demand is much flatter
than any single model's, so N static pools sized for their own peaks
waste their troughs while a shared pool follows the crests around.

* ``static`` arm: each model owns ``POOL/N`` devices for the whole run —
  the provision-for-peak baseline.  No scaling, no parking.
* ``fleet`` arm: one ``FleetDriver`` over the same total pool; models
  boot small, scale with per-model SLO estimators, park to the
  pinned-host tier through idle troughs, and cold-start (unpark) on the
  next queued request with the H2D window hiding the AOT compile.

Acceptance (asserted): the fleet arm matches or beats the static arm's
request-weighted aggregate SLO attainment at strictly fewer
device-hours.  Emits per-model + aggregate columns and the
devices-provisioned timeline in the run.py ``--json`` schema.
"""
from __future__ import annotations

from benchmarks.common import Table
from repro.configs import get_config
from repro.core.coordinator import ScalingPolicy
from repro.serving.fleet import FleetConfig, FleetDriver, FleetModelSpec
from repro.serving.metrics import SLO, fleet_summary
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import fleet_workload

MODEL = "deepseek-v2-lite-16b"
NAMES = ["chat", "code", "batch"]
TP = 2
POOL = 12                       # shared pool == Σ static allocations
STATIC_NDEV = POOL // len(NAMES)
FLEET_BOOT_NDEV = 2             # fleet models boot small and earn devices
DURATION_S = 600.0              # arrival window (one diurnal period)
TAIL_S = 720.0                  # run past the window so queues drain
SLO_TARGET = SLO(ttft_s=15.0, tpot_s=1.5)


def _sim(ndev: int) -> ServingSimulator:
    return ServingSimulator(get_config(MODEL), tp=TP, ndev=ndev,
                            kv_mode="paged", expert_mode="pooled",
                            staging="overlap")


def _workload(seed: int = 0):
    """Staggered diurnal (phase i/N) + a burst at each model's crest.
    Each arm regenerates with the same seed: Request objects are mutated
    by the backend, so arms must never share them."""
    return fleet_workload(NAMES, duration_s=DURATION_S, base_rps=0.0,
                          peak_rps=8.0, period_s=DURATION_S,
                          burst_rps=3.0, burst_width_s=25.0,
                          prompt_len=2000, output_range=(500, 750),
                          seed=seed)


def _run_static(wl):
    sims = {}
    for name in NAMES:
        sim = _sim(STATIC_NDEV)
        sim.run(wl[name], until=TAIL_S)
        sims[name] = sim
    per_model = {n: s.finished for n, s in sims.items()}
    device_seconds = {n: STATIC_NDEV * TAIL_S for n in NAMES}
    return fleet_summary(per_model, SLO_TARGET, device_seconds), None


def _run_fleet(wl):
    policy = ScalingPolicy(slo=SLO_TARGET, window=16, cooldown_s=10.0,
                           queue_scale_up=4, confirm_s=1.0,
                           idle_utilization=0.4)
    specs = [FleetModelSpec(name=n, backend=_sim(FLEET_BOOT_NDEV),
                            policy=policy, mcfg=get_config(MODEL), tp=TP,
                            min_devices=0, park_after_idle_s=15.0)
             for n in NAMES]
    fd = FleetDriver(specs, range(POOL),
                     FleetConfig(dt=0.05, settle_s=5.0, step_dp=1,
                                 max_step_dp=3, sample_every_s=10.0))
    res = fd.run(wl, until=TAIL_S)
    return fleet_summary(res, SLO_TARGET, fd.device_seconds()), fd


def _cold_start_wall(fd, name=None) -> float:
    """Modelled unpark wall (the cold-start cost actually paid; see
    EXPERIMENTS.md for its measurement pitfalls) — per model, or
    fleet-total when ``name`` is None."""
    if fd is None:
        return 0.0
    states = fd.states.values() if name is None else [fd.states[name]]
    return sum(ev.get("wall_s", 0.0)
               for st in states
               for ev in st.spec.backend.park_events
               if ev["kind"] == "unpark")


def run():
    t = Table("fleet", ["arm", "model", "slo_att", "finished",
                        "device_hours", "parks", "unparks",
                        "cold_start_wall_s"])
    tl = Table("fleet_timeline", ["t_s", *NAMES, "free"])
    results = {}
    for arm, runner in (("static", _run_static), ("fleet", _run_fleet)):
        fs, fd = runner(_workload(seed=7))
        results[arm] = fs
        moves = fd.summary() if fd is not None else {}
        for name in NAMES:
            pm = fs["per_model"][name]
            mv = moves.get(name, {})
            t.add(arm, name, pm["slo_attainment"], pm["finished"],
                  pm["device_hours"], mv.get("parks", 0),
                  mv.get("unparks", 0),
                  _cold_start_wall(fd, name) if fd is not None else 0.0)
        t.add(arm, "aggregate", fs["aggregate_slo_attainment"],
              fs["finished"], fs["device_hours"],
              sum(m.get("parks", 0) for m in moves.values()),
              sum(m.get("unparks", 0) for m in moves.values()),
              _cold_start_wall(fd))
        if fd is not None:
            for row in fd.timeline:
                tl.add(row["t"], *(row[n] for n in NAMES), row["free"])
            fd.check_invariants()
    static, fleet = results["static"], results["fleet"]
    assert fleet["finished"] == fleet["n"], \
        f"fleet arm left requests unfinished ({fleet['finished']}/{fleet['n']})"
    assert fleet["aggregate_slo_attainment"] >= \
        static["aggregate_slo_attainment"], \
        (f"fleet SLO {fleet['aggregate_slo_attainment']:.3f} < "
         f"static {static['aggregate_slo_attainment']:.3f}")
    assert fleet["device_hours"] < static["device_hours"], \
        (f"fleet device-hours {fleet['device_hours']:.2f} !< "
         f"static {static['device_hours']:.2f}")
    print(f"fleet beats static: SLO {fleet['aggregate_slo_attainment']:.3f}"
          f" >= {static['aggregate_slo_attainment']:.3f} at "
          f"{fleet['device_hours']:.2f} < {static['device_hours']:.2f} "
          f"device-hours")
    return [t, tl]


if __name__ == "__main__":
    for table in run():
        table.show()
