"""Overlapped staging (beyond-paper CI smoke) — serial vs background
TransferEngine scale-up on the REAL engine, plus the cost-model projection
on the paper models.

Two tables:

* ``overlap_measured`` — each staging mode runs in its own subprocess
  (8 virtual host devices, cold jit caches — in-process A/B timing would
  let the second run ride the first run's compile cache): boot at 4
  devices, pre-initialize the target, then scale 4->6 while decoding a
  live batch.  Every transfer op is padded by a fixed 40 ms in BOTH modes
  so the tiny host model's staging window emulates paper-scale transfer
  durations (serial pays the pad inline on the serve loop, overlap on the
  background workers; bytes and tokens are unaffected).  Reported per mode: scale-up wall-clock (``start_scale`` ->
  task DONE), decode ticks that ran while transfer ops were in flight,
  tokens/s during the scaling window, serve-loop stall, and overlap
  efficiency (Σ per-op transfer time / staging wall-clock).  The run
  asserts the paper's decoupling claim end-to-end: overlap wall-clock
  strictly below serial, byte-identical ``TransferStats``, and
  bit-identical tokens between the two modes.
* ``overlap_projected`` — ``costmodel.plan_cost(staging=...)`` on the
  paper models: overlapped scale-up latency (warmup hidden under the
  transfer window, transfers slowed by the HBM/link contention factor)
  and modelled decode-stall seconds vs the serial sum (DESIGN.md §3).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import PAPER_MODELS, Table, scale_cost

CODE = r"""
import json, time, sys
import numpy as np
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.core.hmm import TransferStats
from repro.serving.driver import ScalePhase
from repro.serving.workload import Request

MODE = sys.argv[1]
MCFG = ModelConfig(name="bench-moe", arch_type="moe", num_layers=4,
                   d_model=128, vocab_size=256, num_heads=8, num_kv_heads=8,
                   head_dim=16, d_ff=256, num_experts=24, top_k=2,
                   moe_d_ff=256, dtype="float32", capacity_factor=100.0)
c4 = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5))

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=512,
                    prefill_buckets=(32,), seed=0, staging=MODE)
srv.boot(c4)
srv.preinitialize(c6)          # warm compile, as the driver's prewarm does

# pad every transfer op by a fixed 40 ms — IDENTICALLY in both modes — so
# the tiny host model's staging window emulates paper-scale transfer
# durations and tokens/s during the window is measurable.  Byte accounting
# and tokens are unaffected; the pad cancels out in the serial-vs-overlap
# comparison (serial pays it inline on the serve loop, overlap on the
# background workers).
OP_PAD_S = 0.04
_orig_unit = srv.hmm._stage_unit
def _padded_unit(*a, **k):
    time.sleep(OP_PAD_S)
    return _orig_unit(*a, **k)
srv.hmm._stage_unit = _padded_unit

rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 300, prompt=rng.integers(0, 256, 16))
        for i in range(4)]
for r in reqs:
    srv.submit(r)

def total_tokens():
    return sum(len(v) for v in srv.engine.generated.values())

t, n = 0.0, 0
for _ in range(5):             # settle the batch before the scale command
    srv.tick(t); t += 0.1; n += 1

t0 = time.perf_counter()
task = srv.start_scale(c6)
tok0, in_flight_ticks, stage_wall = total_tokens(), 0, None
while not task.done:
    srv.tick(t); t += 0.1; n += 1
    if task.phase is ScalePhase.STAGING and srv.hmm.staging_in_flight:
        in_flight_ticks += 1
    task.advance(t)
    if stage_wall is None and task.event is not None:
        stage_wall = time.perf_counter() - t0   # STAGING (∥ COMPILING) done
    assert n < 20000
scale_wall = time.perf_counter() - t0
window_toks = total_tokens() - tok0

while any(r.finish_s is None for r in reqs):
    srv.tick(t); t += 0.1; n += 1
    assert n < 20000

st = task.stage_stats
print("JSON:" + json.dumps(dict(
    mode=MODE, scale_wall_s=scale_wall, stage_wall_s=stage_wall,
    in_flight_ticks=in_flight_ticks,
    window_toks=window_toks, window_tok_s=window_toks / scale_wall,
    stall_s=task.stall_s, overlap_eff=task.overlap_efficiency,
    stats={f: getattr(st, f) for f in TransferStats.BYTE_FIELDS},
    tokens={str(r.rid): srv.engine.generated[r.rid] for r in reqs})))
"""

TRANSITIONS = [(4, 6), (6, 8)]


def _run_mode(mode: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", CODE, mode], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("JSON:")][0][5:])


def run():
    serial = _run_mode("serial")
    overlap = _run_mode("overlap")
    # the acceptance triplet: less wall-clock, same bytes, same tokens
    assert overlap["scale_wall_s"] < serial["scale_wall_s"], \
        (overlap["scale_wall_s"], serial["scale_wall_s"])
    assert overlap["stats"] == serial["stats"], (overlap["stats"],
                                                 serial["stats"])
    assert overlap["tokens"] == serial["tokens"]

    meas = Table("overlap_measured",
                 ["staging", "scale_wall_s", "stage_wall_s",
                  "in_flight_ticks", "window_tok_s", "stall_s",
                  "overlap_eff"])
    for row in (serial, overlap):
        meas.add(row["mode"], row["scale_wall_s"], row["stage_wall_s"],
                 row["in_flight_ticks"], row["window_tok_s"],
                 row["stall_s"],
                 row["overlap_eff"] if row["overlap_eff"] is not None
                 else float("nan"))

    proj = Table("overlap_projected",
                 ["model", "transition", "serial_s", "overlap_s",
                  "serial_stall_s", "overlap_stall_s"])
    for name in PAPER_MODELS:
        for n_old, n_new in TRANSITIONS:
            _, cs = scale_cost(name, n_old, n_new, "elastic",
                               staging="serial")
            _, co = scale_cost(name, n_old, n_new, "elastic",
                               staging="overlap")
            assert co.scale_time_s <= cs.scale_time_s, (name, n_old, n_new)
            assert co.decode_stall_s < cs.decode_stall_s, (name, n_old,
                                                           n_new)
            proj.add(name, f"{n_old}->{n_new}", cs.scale_time_s,
                     co.scale_time_s, cs.decode_stall_s, co.decode_stall_s)
    return [meas, proj]


def main():
    for t in run():
        t.show()
    print("\noverlapped staging: same bytes, bit-identical tokens, "
          "strictly lower scale-up wall-clock (asserted above)")


if __name__ == "__main__":
    main()
