"""Fig. 10 — SLO compliance vs request rate (DeepSeek-V2-Lite, TTFT<=1s,
TPOT<=1s, prompts 2000 tok, decode 500-750, reactive scale-up mid-run)."""
import numpy as np

from benchmarks.common import Table
from repro.configs import get_config
from repro.serving.metrics import SLO, slo_attainment
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import fixed_rate, make_workload

MODEL = "deepseek-v2-lite-16b"
STRATS = ["elastic", "cold_restart", "colocated"]
LABELS = {"elastic": "ElasticMoE", "cold_restart": "Naive Cold Start",
          "colocated": "Concurrent Vertical"}


def run() -> Table:
    mcfg = get_config(MODEL)
    slo = SLO(ttft_s=1.0, tpot_s=1.0)
    t = Table("fig10_slo_vs_rps", ["rps"] + [LABELS[s] for s in STRATS])
    for rps in [1, 2, 4, 6, 8, 9, 10, 12]:
        row = [rps]
        for strat in STRATS:
            sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy=strat)
            reqs = make_workload(duration_s=120.0, rps_fn=fixed_rate(rps),
                                 prompt_len=2000, output_range=(500, 750),
                                 seed=1)
            sim.run(reqs, until=30.0)
            sim.command_scale(6)          # reactive scale-up at fixed time
            sim.run([], until=150.0)
            row.append(slo_attainment(reqs, slo))
        t.add(*row)
    return t


def main():
    t = run()
    t.show()
    for s, lbl in LABELS.items():
        col = [r[1 + STRATS.index(s)] for r in t.rows]
        ok = [r[0] for r, v in zip(t.rows, col) if v == v and v >= 0.9]
        print(f"  {lbl}: sustains >=90% SLO up to ~{max(ok) if ok else 0} rps")


if __name__ == "__main__":
    main()
