"""Table 2 — offline throughput before/during/after a scale-up (DeepSeek-
V2-Lite, DP3TP2 -> DP4TP2, 10000-request batch, 500 prefill/250-500 decode)."""
from benchmarks.common import Table
from repro.configs import get_config
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import make_workload, fixed_rate

MODEL = "deepseek-v2-lite-16b"
STRATS = ["colocated", "cold_restart", "elastic"]
LABELS = {"colocated": "Vertical (Concurrent)",
          "cold_restart": "Vertical (Cold Restart)",
          "elastic": "Elastic (Ours)"}


def run() -> Table:
    mcfg = get_config(MODEL)
    t = Table("table2_throughput_rps", ["method", "before", "during", "after"])
    sims = {}
    scale_at = 120.0
    for strat in STRATS:
        sim = ServingSimulator(mcfg, tp=2, ndev=6, strategy=strat,
                               kv_seq_len=1024)
        reqs = make_workload(duration_s=600.0, rps_fn=fixed_rate(50.0),
                             prompt_len=500, output_range=(250, 500), seed=2)
        sim.run(reqs, until=scale_at)
        sim.command_scale(8)
        sim.run([], until=600.0)
        sims[strat] = sim
    # "during" window: +-5s around the longest transition (cold restart)
    longest = max(s.events[0].t_ready - s.events[0].t_command
                  for s in sims.values())
    w0, w1 = scale_at - 5.0, scale_at + longest + 5.0
    for strat in STRATS:
        sim = sims[strat]
        t.add(LABELS[strat],
              sim.throughput(60.0, scale_at),
              sim.throughput(w0, w1),
              sim.throughput(w1, min(w1 + 120.0, 600.0)))
    return t


def main():
    t = run()
    t.show()
    ours = t.rows[-1]
    cold = t.rows[1]
    print(f"  during-scaling throughput: ours {ours[2]:.2f} vs cold-restart "
          f"{cold[2]:.2f} rps ({ours[2] / max(cold[2], 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
