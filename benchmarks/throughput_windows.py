"""Table 2 — offline throughput before/during/after a scale-up (DeepSeek-
V2-Lite, DP3TP2 -> DP4TP2, 10000-request batch, 500 prefill/250-500 decode).

The extra "Elastic (closed loop)" row replaces the scripted t=120 command
with the ClusterDriver deciding from backlog — same shared engine semantics,
autonomous timing."""
from benchmarks.common import Table
from repro.configs import get_config
from repro.core.coordinator import ScalingPolicy
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import make_workload, fixed_rate

MODEL = "deepseek-v2-lite-16b"
STRATS = ["colocated", "cold_restart", "elastic"]
LABELS = {"colocated": "Vertical (Concurrent)",
          "cold_restart": "Vertical (Cold Restart)",
          "elastic": "Elastic (Ours)"}


def _closed_loop_sim(mcfg, reqs):
    sim = ServingSimulator(mcfg, tp=2, ndev=6, strategy="elastic",
                           kv_seq_len=1024)
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=30.0, queue_scale_up=16)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=30.0,
                                               min_dp=3))
    driver.run(reqs, until=600.0)
    return sim


def run() -> Table:
    mcfg = get_config(MODEL)
    t = Table("table2_throughput_rps", ["method", "before", "during", "after"])
    sims = {}
    scale_at = 120.0
    for strat in STRATS:
        sim = ServingSimulator(mcfg, tp=2, ndev=6, strategy=strat,
                               kv_seq_len=1024)
        reqs = make_workload(duration_s=600.0, rps_fn=fixed_rate(50.0),
                             prompt_len=500, output_range=(250, 500), seed=2)
        sim.run(reqs, until=scale_at)
        sim.command_scale(8)
        sim.run([], until=600.0)
        sims[strat] = sim
    closed = _closed_loop_sim(
        mcfg, make_workload(duration_s=600.0, rps_fn=fixed_rate(50.0),
                            prompt_len=500, output_range=(250, 500), seed=2))
    # "during" window: +-5s around the longest transition (cold restart)
    longest = max(s.events[0].t_ready - s.events[0].t_command
                  for s in sims.values())
    w0, w1 = scale_at - 5.0, scale_at + longest + 5.0
    for strat in STRATS:
        sim = sims[strat]
        t.add(LABELS[strat],
              sim.throughput(60.0, scale_at),
              sim.throughput(w0, w1),
              sim.throughput(w1, min(w1 + 120.0, 600.0)))
    # the driver picks its own moment to scale: anchor the closed-loop
    # row's before/during/after windows to ITS transition, not the
    # scripted t=120 command
    if closed.events:
        ev = closed.events[0]
        cw0, cw1 = ev.t_command - 5.0, ev.t_ready + 5.0
        t.add("Elastic (closed loop)",
              closed.throughput(max(0.0, cw0 - 60.0), cw0),
              closed.throughput(cw0, cw1),
              closed.throughput(cw1, min(cw1 + 120.0, 600.0)))
    else:
        t.add("Elastic (closed loop)", closed.throughput(60.0, 600.0),
              float("nan"), float("nan"))
    return t


def main():
    t = run()
    t.show()
    ours = t.rows[2]
    cold = t.rows[1]
    print(f"  during-scaling throughput: ours {ours[2]:.2f} vs cold-restart "
          f"{cold[2]:.2f} rps ({ours[2] / max(cold[2], 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
