"""Fig. 11 — latency breakdown of an ElasticMoE scale-up
(Qwen3-30B-A3B, 12->16 NPUs)."""
from benchmarks.common import Table, scale_cost


def run() -> Table:
    t = Table("fig11_latency_breakdown_s", ["phase", "seconds"])
    _, cost = scale_cost("qwen3-30b-a3b", 12, 16, "elastic")
    order = ["warmup", "p2p", "zero_copy", "init", "disk"]
    label = {"warmup": "model warmup", "p2p": "P2P weight transfers",
             "zero_copy": "zero-copy mapping", "init": "KV-cache init",
             "disk": "disk I/O"}
    for k in order:
        t.add(label[k], cost.breakdown.get(k, 0.0))
    t.add("TOTAL", cost.scale_time_s)
    return t


def main():
    t = run()
    t.show()
    print("  (warmup dominates; reconfiguration itself is sub-second — "
          "matches the paper's Fig. 11 finding)")


if __name__ == "__main__":
    main()
