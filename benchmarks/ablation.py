"""Tables 1 & 3 — progressive ablation of ElasticMoE components on
DP3->DP4 (scale-up) and DP4->DP3 (scale-down), DeepSeek-V2-Lite, TP2."""
from benchmarks.common import Table, scale_cost

ABLATIONS = [
    ("ElasticMoE (full)", {}),
    ("- IPCAlloc", {"ipc_safe_alloc": False}),
    ("- HCCL", {"ipc_safe_alloc": False, "hccl": False}),
    ("- PreInit", {"ipc_safe_alloc": False, "hccl": False, "preinit": False}),
    ("- ZeroCopy", {"ipc_safe_alloc": False, "hccl": False, "preinit": False,
                    "zero_copy": False}),
]


def run_one(n0: int, n1: int, name: str) -> Table:
    t = Table(name, ["configuration", "scale_time_s", "downtime_s",
                     "peak_mem_gb"])
    for label, flags in ABLATIONS:
        pre = flags.pop("preinit", True)
        _, cost = scale_cost("deepseek-v2-lite-16b", n0, n1, "elastic",
                             preinit=pre, **flags)
        flags["preinit"] = pre
        t.add(label, cost.scale_time_s, cost.downtime_s, cost.peak_mem_gb)
    return t


def run():
    return [run_one(6, 8, "table1_ablation_scale_up_dp3_dp4"),
            run_one(8, 6, "table3_ablation_scale_down_dp4_dp3")]


def main():
    for t in run():
        t.show()


if __name__ == "__main__":
    main()
