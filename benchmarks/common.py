"""Shared glue for the paper-figure benchmarks."""
from __future__ import annotations

import io
import sys
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core.costmodel import DEFAULT_HW, plan_cost
from repro.core.scaling_plan import (Op, STRATEGIES, placement, plan_elastic,
                                     plan_elastic_min_move)
from repro.core.topology import ElasticConfig, kv_cache_bytes, model_tensors

PAPER_MODELS = ["deepseek-v2-lite-16b", "qwen3-30b-a3b", "deepseek-v3"]
TP_OF = {"deepseek-v2-lite-16b": 2, "qwen3-30b-a3b": 2, "deepseek-v3": 2}

STRATEGY_LABELS = {
    "elastic": "ElasticMoE (ours)",
    "cold_restart": "Vertical (Cold Restart)",
    "extravagant": "Vertical (Extravagant)",
    "colocated": "Vertical (Colocated)",
    "horizontal": "Horizontal (Replica)",
}


def tensors_for(name: str, tp: int, kv_batch: int = 8, kv_len: int = 4096,
                kv_dtype: Optional[str] = None,
                expert_dtype: Optional[str] = None):
    mcfg = get_config(name)
    kvb = kv_cache_bytes(mcfg, kv_batch, kv_len, kv_dtype=kv_dtype)
    return mcfg, model_tensors(mcfg, tp, kv_bytes_per_replica=kvb,
                               expert_dtype=expert_dtype)


def cfg_of(n: int, tp: int, base: int = 0) -> ElasticConfig:
    return ElasticConfig(dp=n // tp, tp=tp,
                         devices=tuple(range(base, base + n)))


def scale_cost(name: str, n_old: int, n_new: int, strategy: str,
               preinit: bool = True, paged: bool = True,
               kv_dtype: Optional[str] = None,
               expert_dtype: Optional[str] = None, **flags):
    """Plan + cost for one transition under one strategy."""
    tp = TP_OF.get(name, 2)
    mcfg, tensors = tensors_for(name, tp, kv_dtype=kv_dtype,
                                expert_dtype=expert_dtype)
    old = cfg_of(n_old, tp)
    if strategy in ("extravagant", "horizontal"):
        new = cfg_of(n_new, tp, base=n_old)
    else:
        new = cfg_of(n_new, tp)
    if strategy == "elastic" and paged and mcfg.is_moe:
        plan = plan_elastic_min_move(tensors, old, new, mcfg)
    else:
        plan = STRATEGIES[strategy](tensors, old, new)
    resident = {d: sum(s.values())
                for d, s in placement(tensors, old).items()}
    return plan, plan_cost(plan, preinit=preinit, strategy=strategy,
                           resident_bytes_per_device=resident, **flags)


def feasible(strategy: str, n_old: int, n_new: int, total_devices: int = 384):
    if strategy == "horizontal":
        return n_new == 2 * n_old and n_old + n_new <= total_devices
    if strategy == "extravagant":
        return n_old + n_new <= total_devices
    return True


class Table:
    def __init__(self, name: str, cols: List[str]):
        self.name = name
        self.cols = cols
        self.rows: List[List] = []

    def add(self, *vals):
        self.rows.append(list(vals))

    def show(self, file=sys.stdout):
        print(f"\n## {self.name}", file=file)
        widths = [max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.cols)]
        print("  ".join(str(c).ljust(w) for c, w in zip(self.cols, widths)),
              file=file)
        for r in self.rows:
            print("  ".join(_fmt(v).ljust(w) for v, w in zip(r, widths)),
                  file=file)

    def csv_rows(self):
        for r in self.rows:
            yield f"{self.name}," + ",".join(_fmt(v) for v in r)


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)
