"""Fig. 4b — per-device model-weight memory vs EP degree.

Shows why horizontal scaling (which caps EP at the per-instance degree)
wastes HBM: expert weights dominate and shrink ~1/EP."""
from benchmarks.common import PAPER_MODELS, TP_OF, Table, cfg_of, tensors_for
from repro.core.scaling_plan import placement


def run() -> Table:
    t = Table("fig4b_weight_gb_per_device",
              ["model"] + [f"EP{e}" for e in (2, 4, 8, 16, 32)])
    for model in PAPER_MODELS:
        tp = TP_OF[model]
        mcfg, tensors = tensors_for(model, tp)
        weights = [x for x in tensors if x.kind != "kv"]
        row = [model]
        for ep in (2, 4, 8, 16, 32):
            place = placement(weights, cfg_of(ep, tp))
            row.append(max(sum(s.values()) for s in place.values()) / 1e9)
        t.add(*row)
    return t


def main():
    run().show()


if __name__ == "__main__":
    main()
