"""Fig. 9 — SLO attainment dynamics around a scaling event
(DeepSeek-V2-Lite; scale-up 4->6 and scale-down 6->4; discrete-event sim).

``run_closed_loop`` additionally replays the scale-up scenario with *no
scripted command*: the ClusterDriver's SLO-aware loop decides when and how
far to scale (the paper's §4.3 coordinator, closed over the simulator)."""
import functools

import numpy as np

from benchmarks.common import Table
from repro.configs import get_config
from repro.core.coordinator import ScalingPolicy
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO, slo_attainment_timeline
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import make_workload, step_up

MODEL = "deepseek-v2-lite-16b"
STRATS = ["elastic", "cold_restart", "colocated"]


@functools.lru_cache(maxsize=None)  # run_closed_loop reuses run(True)'s sims
def _run(strategy: str, up: bool):
    mcfg = get_config(MODEL)
    n0, n1 = (4, 6) if up else (6, 4)
    sim = ServingSimulator(mcfg, tp=2, ndev=n0, strategy=strategy)
    rps0 = 0.7 * _sustainable_rps(sim, n0)
    rps1 = (1.3 if up else 0.45) * _sustainable_rps(sim, n0)
    reqs = make_workload(duration_s=240.0, rps_fn=step_up(rps0, rps1, 60.0),
                         prompt_len=2000, output_range=(500, 750), seed=0)
    # scaling command issued shortly after the load shift
    sim.run(reqs, until=75.0)
    sim.command_scale(n1)
    sim.run([], until=240.0)
    return reqs, sim


def _sustainable_rps(sim, ndev):
    per_req_s = (sim.perf.prefill_s(2000, ndev)
                 + 625 * sim.perf.decode_step_s(32, ndev))
    batch = min(sim.perf.max_batch(ndev), 64)
    return batch / per_req_s


def run(up=True) -> Table:
    slo = SLO(ttft_s=5.0, tpot_s=1.5) if up else SLO(ttft_s=2.0, tpot_s=1.0)
    name = "fig9a_scaleup_slo_timeline" if up else "fig9b_scaledown_slo_timeline"
    t = Table(name, ["t_s"] + STRATS + ([f"{s}_per_npu" for s in STRATS]
                                        if not up else []))
    runs = {s: _run(s, up) for s in STRATS}
    grids = {}
    for s, (reqs, sim) in runs.items():
        ts, att = slo_attainment_timeline(reqs, slo, window_s=20.0, dt=5.0)
        grids[s] = dict(zip(np.round(ts, 1), att))
    for tt in np.arange(50.0, 240.0, 10.0):
        row = [tt] + [grids[s].get(tt, float("nan")) for s in STRATS]
        if not up:
            for s in STRATS:
                ndev = runs[s][1].ndev + runs[s][1].extra_devices_during_scale
                a = grids[s].get(tt, float("nan"))
                row.append(a / max(ndev, 1))
        t.add(*row)
    return t


def run_closed_loop() -> Table:
    """Same load shift as fig9a, but the driver decides: scripted scale-up
    at t=75 vs the closed loop reacting to backlog/attainment on its own."""
    mcfg = get_config(MODEL)
    slo = SLO(ttft_s=5.0, tpot_s=1.5)
    scripted_reqs, scripted_sim = _run("elastic", True)

    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic")
    rps0 = 0.7 * _sustainable_rps(sim, 4)
    rps1 = 1.3 * _sustainable_rps(sim, 4)
    reqs = make_workload(duration_s=240.0, rps_fn=step_up(rps0, rps1, 60.0),
                         prompt_len=2000, output_range=(500, 750), seed=0)
    policy = ScalingPolicy(slo=slo, window=16, cooldown_s=20.0,
                           queue_scale_up=8, confirm_s=2.0)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=15.0,
                                               min_dp=2))
    driver.run(reqs, until=240.0)

    t = Table("fig9c_closed_loop_slo_timeline",
              ["t_s", "scripted", "closed_loop", "driver_ndev"])
    grids = {}
    for name, (rr, ss) in (("scripted", (scripted_reqs, scripted_sim)),
                           ("closed_loop", (reqs, sim))):
        ts, att = slo_attainment_timeline(rr, slo, window_s=20.0, dt=5.0)
        grids[name] = dict(zip(np.round(ts, 1), att))
    ndev_at = sorted((e.t_command, e.new_ndev) for e in sim.events)
    for tt in np.arange(50.0, 240.0, 10.0):
        ndev = 4
        for tc, nd in ndev_at:
            if tc <= tt:
                ndev = nd
        t.add(tt, grids["scripted"].get(tt, float("nan")),
              grids["closed_loop"].get(tt, float("nan")), ndev)
    return t


def main():
    for up in (True, False):
        t = run(up)
        t.show()
        # summary: post-event recovery time to >=0.9
        print()
    run_closed_loop().show()


if __name__ == "__main__":
    main()
