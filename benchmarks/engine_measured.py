"""MEASURED (not modelled) elastic scaling on real host devices: wall-clock
stage/switch times, exact zero-copy vs P2P byte counts, and compile-cache
effect — the ground truth behind the cost-model figures.

Runs in a subprocess with 8 virtual host devices so the main process keeps
the default single device.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import Table

CODE = r"""
import json, time
import numpy as np
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

MCFG = ModelConfig(name="bench-moe", arch_type="moe", num_layers=4,
                   d_model=128, vocab_size=256, num_heads=8, num_kv_heads=8,
                   head_dim=16, d_ff=256, num_experts=24, top_k=2,
                   moe_d_ff=64, dtype="float32", capacity_factor=100.0)

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=96,
                    prefill_buckets=(32,), seed=0)
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
c8 = ElasticConfig(dp=4, tp=2, devices=(0,1,2,3,4,5,6,7))

t0 = time.perf_counter(); srv.boot(c4); boot_s = time.perf_counter() - t0
rows = []
for tgt, pre in [(c6, True), (c8, False)]:
    if pre:
        t0 = time.perf_counter(); srv.preinitialize(tgt)
        pre_s = time.perf_counter() - t0
    else:
        pre_s = 0.0
    rng = np.random.default_rng(0)
    for i in range(2):
        srv.submit(Request(100+i+tgt.ndev*10, 0.0, 16, 40,
                           prompt=rng.integers(0, 256, 16)))
    srv.tick(0.0)
    ev = srv.stage_scale(tgt)
    srv.tick(0.1)          # serving during staging (zero downtime)
    t0 = time.perf_counter(); srv.switchover()
    sw = time.perf_counter() - t0
    st = ev.stats
    rows.append(dict(transition=f"{ev.src.split('@')[0]}->{ev.dst.split('@')[0]}",
                     preinited=pre, preinit_s=round(pre_s, 3),
                     stage_s=round(ev.stage_s, 3), switch_s=round(sw, 3),
                     zero_copy_mb=round(st.zero_copy_bytes/1e6, 2),
                     p2p_mb=round(st.p2p_bytes/1e6, 2),
                     local_mb=round(st.local_bytes/1e6, 2),
                     zero_copy_n=st.zero_copy_count, p2p_n=st.p2p_count))
print("JSON:" + json.dumps(dict(boot_s=round(boot_s, 3), rows=rows)))
"""


def run() -> Table:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    data = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("JSON:")][0][5:])
    t = Table("measured_engine_scaling",
              ["transition", "preinited", "preinit_s", "stage_s", "switch_s",
               "zero_copy_mb", "p2p_mb", "local_mb"])
    for row in data["rows"]:
        t.add(row["transition"], row["preinited"], row["preinit_s"],
              row["stage_s"], row["switch_s"], row["zero_copy_mb"],
              row["p2p_mb"], row["local_mb"])
    t.boot_s = data["boot_s"]
    return t


def main():
    t = run()
    t.show()
    print(f"  cold boot: {t.boot_s:.2f}s; pre-initialized scale stage+switch "
          f"is 10-100x cheaper than boot — the paper's core claim, measured")


if __name__ == "__main__":
    main()
