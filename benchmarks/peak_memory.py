"""Fig. 8 — peak per-device memory during scale-up (DeepSeek-V2-Lite)."""
from benchmarks.common import STRATEGY_LABELS, Table, feasible, scale_cost


def run() -> Table:
    t = Table("fig8_peak_memory_gb", ["transition"] + list(STRATEGY_LABELS))
    for n0, n1 in [(2, 4), (4, 6), (6, 8)]:
        row = [f"{n0}->{n1}"]
        for strat in STRATEGY_LABELS:
            n1_eff = 2 * n0 if strat == "horizontal" else n1
            if not feasible(strat, n0, n1_eff):
                row.append("n/a")
                continue
            _, cost = scale_cost("deepseek-v2-lite-16b", n0, n1_eff, strat)
            row.append(cost.peak_mem_gb)
        t.add(*row)
    return t


def main():
    t = run()
    t.show()
    for r in t.rows:
        ours, cold = r[1], r[2]
        extrav = r[3]
        print(f"  {r[0]}: ours {ours:.1f}GB vs cold-restart {cold:.1f}GB "
              f"(+{100 * (ours / cold - 1):.1f}%), vs extravagant+colocated "
              f"worst {max(v for v in r[3:] if isinstance(v, float)):.1f}GB")


if __name__ == "__main__":
    main()
