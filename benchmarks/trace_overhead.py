"""Tracing overhead A/B (beyond-paper CI smoke) — the serve loop with the
NULL tracer vs a live ``obs.Tracer`` with routing histograms enabled.

Each arm runs in its own subprocess (8 virtual host devices, cold jit
caches — in-process A/B would let the second arm ride the first arm's
compile cache): boot at 4 devices, decode a live 4-request batch to
completion, and report steady-state tokens/s over the serve loop.  The
``traced`` arm installs a Tracer, samples expert-routing histograms every
other tick, exports the Chrome trace, and validates it; the ``null`` arm
leaves the default ``NULL_TRACER`` installed, exercising the disabled
fast path every instrumented call site takes when tracing is off.

The run asserts the disabled path keeps >= 98%% of the traced arm's
tokens/s — the instrumentation's "free when off" budget (DESIGN.md §9).
The exported trace artifact path is printed so CI can upload it.
"""
import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import Table

CODE = r"""
import json, time, sys
import numpy as np
from repro import obs
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

MODE = sys.argv[1]                       # "null" | "traced"
TRACE_PATH = sys.argv[2] if len(sys.argv) > 2 else None
MCFG = ModelConfig(name="bench-moe", arch_type="moe", num_layers=4,
                   d_model=128, vocab_size=256, num_heads=8, num_kv_heads=8,
                   head_dim=16, d_ff=256, num_experts=24, top_k=2,
                   moe_d_ff=256, dtype="float32", capacity_factor=100.0)

tr = None
if MODE == "traced":
    tr = obs.install(obs.Tracer(capacity=500_000))

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=512,
                    prefill_buckets=(32,), seed=0,
                    routing_sample_every=2 if MODE == "traced" else 0)
srv.boot(ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3)))

rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 200, prompt=rng.integers(0, 256, 16))
        for i in range(4)]
for r in reqs:
    srv.submit(r)

def total_tokens():
    return sum(len(v) for v in srv.engine.generated.values())

t, n = 0.0, 0
for _ in range(10):                      # warmup: admit + compile settle
    srv.tick(t); t += 0.1; n += 1

tok0, t0 = total_tokens(), time.perf_counter()
while any(r.finish_s is None for r in reqs):
    srv.tick(t); t += 0.1; n += 1
    assert n < 20000
wall = time.perf_counter() - t0
toks = total_tokens() - tok0

n_events = 0
if MODE == "traced":
    doc = obs.write_chrome_trace(TRACE_PATH, tr,
                                 extra_metadata={"bench": "trace_overhead"})
    obs.validate_trace(doc)
    n_events = len([r for r in doc["traceEvents"] if r["ph"] != "M"])
    rt = srv.routing_stats()
    assert rt is not None and rt["samples"] >= 1, rt
else:
    assert obs.get_tracer() is obs.NULL_TRACER
    assert obs.NULL_TRACER.events() == []

print("JSON:" + json.dumps(dict(
    mode=MODE, wall_s=wall, tokens=toks, tok_s=toks / wall,
    n_events=n_events)))
"""


def _run_mode(mode: str, trace_path: str | None = None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    argv = [sys.executable, "-c", CODE, mode] + (
        [trace_path] if trace_path else [])
    r = subprocess.run(argv, env=env, capture_output=True, text=True,
                       timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("JSON:")][0][5:])


def _best_of(n: int, mode: str, trace_path: str | None = None) -> dict:
    # host-timing noise only ever slows a run down; best-of-N is the
    # noise-robust estimator of each arm's true throughput
    runs = [_run_mode(mode, trace_path) for _ in range(n)]
    return max(runs, key=lambda r: r["tok_s"])


def run():
    trace_path = os.path.join(tempfile.gettempdir(), "trace_overhead.json")
    traced = _best_of(2, "traced", trace_path)
    null = _best_of(2, "null")
    assert traced["n_events"] > 0
    # the budget: instrumentation must be free when off — the NULL_TRACER
    # arm keeps >= 98% of the traced arm's throughput (it should be the
    # faster arm; the 2% floor absorbs host-timing noise)
    assert null["tok_s"] >= 0.98 * traced["tok_s"], (null["tok_s"],
                                                     traced["tok_s"])
    overhead_pct = 100.0 * (1.0 - traced["tok_s"] / null["tok_s"])

    t = Table("trace_overhead",
              ["tracer", "tokens", "wall_s", "tok_s", "events",
               "overhead_pct"])
    t.add("null", null["tokens"], null["wall_s"], null["tok_s"], 0,
          float("nan"))
    t.add("traced", traced["tokens"], traced["wall_s"], traced["tok_s"],
          traced["n_events"], overhead_pct)
    print(f"trace artifact: {trace_path}")
    return [t]


def main():
    for t in run():
        t.show()
    print("\ntracing A/B: disabled fast path holds >= 98% of traced "
          "throughput (asserted above)")


if __name__ == "__main__":
    main()
