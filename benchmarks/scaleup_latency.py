"""Fig. 7 — scale-up latency across methods for the three paper MoE models.

x-axis: source->destination NPU transitions (fixed 2-NPU steps for
DeepSeek-V2-Lite / Qwen3-30B, progressively larger steps for DeepSeek-V3);
values: projected seconds from the byte-exact plan + calibrated cost model.
"""
from benchmarks.common import (PAPER_MODELS, STRATEGY_LABELS, Table, feasible,
                               scale_cost)

TRANSITIONS = {
    "deepseek-v2-lite-16b": [(2, 4), (4, 6), (6, 8)],
    "qwen3-30b-a3b": [(4, 6), (6, 8), (8, 10)],
    "deepseek-v3": [(16, 18), (16, 20), (16, 24), (16, 32)],
}


def run() -> Table:
    t = Table("fig7_scaleup_latency_s",
              ["model", "transition"] + list(STRATEGY_LABELS))
    for model in PAPER_MODELS:
        for n0, n1 in TRANSITIONS[model]:
            row = [model, f"{n0}->{n1}"]
            for strat in STRATEGY_LABELS:
                if strat == "horizontal":
                    n1_eff = 2 * n0
                else:
                    n1_eff = n1
                if not feasible(strat, n0, n1_eff):
                    row.append("n/a")
                    continue
                _, cost = scale_cost(model, n0, n1_eff, strat)
                row.append(cost.scale_time_s)
            t.add(*row)
    return t


def main():
    t = run()
    t.show()
    # headline: speedup vs best baseline
    for r in t.rows:
        ours = r[2]
        base = min(v for v in r[3:] if isinstance(v, float))
        print(f"  {r[0]} {r[1]}: ElasticMoE {ours:.2f}s vs best baseline "
              f"{base:.2f}s -> {base / ours:.1f}x faster")


if __name__ == "__main__":
    main()
