"""Fig. 1 — scaling granularity: (a) achievable throughput per device count,
(b) devices needed for a target goodput.  Horizontal scaling only moves in
whole-replica quanta and re-replicates experts; ElasticMoE adds devices in
DP/EP steps of 2 and keeps one expert pool."""
from benchmarks.common import Table
from repro.configs import get_config
from repro.serving.simulator import PerfModel

MODEL = "deepseek-v2-lite-16b"
BASE_INSTANCE = 4          # minimal replica size (DP2-TP2)


def _rps(perf, ndev):
    batch = perf.max_batch(ndev)
    step = perf.decode_step_s(batch, ndev)
    return batch / step / 625.0     # 500-750 decode tokens per request


def run() -> Table:
    mcfg = get_config(MODEL)
    perf = PerfModel(mcfg)
    t = Table("fig1_granularity",
              ["ndev", "elastic_rps", "horizontal_rps",
               "elastic_dev_for_rps", "horizontal_dev_for_rps"])
    targets = {}
    for n in range(BASE_INSTANCE, 33, 2):
        e = _rps(perf, n)
        # horizontal: k independent replicas of BASE_INSTANCE
        k = n // BASE_INSTANCE
        h = k * _rps(perf, BASE_INSTANCE)
        t.add(n, e, h, "", "")
    # (b) devices needed for a goodput target
    for i, tgt in enumerate([5.0, 10.0, 20.0, 40.0]):
        e_dev = next(n for n in range(2, 400, 2) if _rps(perf, n) >= tgt)
        h_dev = next(n for n in range(BASE_INSTANCE, 400, BASE_INSTANCE)
                     if (n // BASE_INSTANCE) * _rps(perf, BASE_INSTANCE) >= tgt)
        t.add(f"target={tgt}rps", "", "", e_dev, h_dev)
    return t


def main():
    t = run()
    t.show()
    print("  elastic reaches any target with fewer devices (fine steps + "
          "no expert re-replication) — the paper's Fig. 1 argument")


if __name__ == "__main__":
    main()
