"""Fig. 12 — scale-down latency across methods (appendix A.2)."""
from benchmarks.common import (PAPER_MODELS, STRATEGY_LABELS, Table, feasible,
                               scale_cost)

TRANSITIONS = {
    "deepseek-v2-lite-16b": [(8, 6), (6, 4), (4, 2)],
    "qwen3-30b-a3b": [(10, 8), (8, 6), (6, 4)],
    "deepseek-v3": [(32, 16), (24, 16), (20, 16), (16, 2)],
}


def run() -> Table:
    strategies = {k: v for k, v in STRATEGY_LABELS.items()
                  if k != "horizontal"}
    t = Table("fig12_scaledown_latency_s",
              ["model", "transition"] + list(strategies))
    for model in PAPER_MODELS:
        for n0, n1 in TRANSITIONS[model]:
            row = [model, f"{n0}->{n1}"]
            for strat in strategies:
                if not feasible(strat, n0, n1):
                    row.append("n/a")
                    continue
                _, cost = scale_cost(model, n0, n1, strat)
                row.append(cost.scale_time_s)
            t.add(*row)
    return t


def main():
    t = run()
    t.show()
    for r in t.rows:
        ours = r[2]
        base = min(v for v in r[3:] if isinstance(v, float))
        print(f"  {r[0]} {r[1]}: {ours:.2f}s vs {base:.2f}s "
              f"({ours / base:.2f}x of fastest baseline)")


if __name__ == "__main__":
    main()
