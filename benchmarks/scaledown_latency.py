"""Fig. 12 — scale-down latency across methods (appendix A.2), plus the
beyond-paper MEASURED drain-vs-migrate comparison on the real engine.

Two entry points (benchmarks/run.py registers both):

* ``run()`` (``--only fig12``) — the paper projection: cost-model
  scale-down latency per strategy and transition.
* ``run_measured()`` (``--only scaledown_migrate``, CI smoke) — each
  scale-down policy runs in its own subprocess on the real JAX engine
  (8 virtual host devices): boot at 6 devices with paged KV, park two
  LONG-output sequences in the doomed partition (plus short fillers that
  free survivor slots), then scale 6->4 mid-decode.

  - ``drain``  — the devices release only after the doomed sequences run
    to completion: scale-down wall is bounded by the longest in-flight
    output (the coarse release ElasticMoE §5.2 argues against).
  - ``migrate``— live KV blocks device-copy onto survivors through the
    background TransferEngine (MIGRATING phase) and the devices release
    in a handful of ticks.

  The run asserts the acceptance criteria end-to-end: migrate-mode wall
  ≥5x lower than drain under the long-output workload, tokens of the
  migrated sequences bit-identical to an unscaled run, zero preemptions
  in migrate mode, and a clean pool (``check_invariants``) after commit.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import (PAPER_MODELS, STRATEGY_LABELS, Table, feasible,
                               scale_cost)

TRANSITIONS = {
    "deepseek-v2-lite-16b": [(8, 6), (6, 4), (4, 2)],
    "qwen3-30b-a3b": [(10, 8), (8, 6), (6, 4)],
    "deepseek-v3": [(32, 16), (24, 16), (20, 16), (16, 2)],
}


def run() -> Table:
    strategies = {k: v for k, v in STRATEGY_LABELS.items()
                  if k != "horizontal"}
    t = Table("fig12_scaledown_latency_s",
              ["model", "transition"] + list(strategies))
    for model in PAPER_MODELS:
        for n0, n1 in TRANSITIONS[model]:
            row = [model, f"{n0}->{n1}"]
            for strat in strategies:
                if not feasible(strat, n0, n1):
                    row.append("n/a")
                    continue
                _, cost = scale_cost(model, n0, n1, strat)
                row.append(cost.scale_time_s)
            t.add(*row)
    return t


# ------------------------------------------- measured drain vs migrate

CODE = r"""
import json, time, sys
import numpy as np
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

MODE = sys.argv[1]
MCFG = ModelConfig(name="bench-moe", arch_type="moe", num_layers=4,
                   d_model=128, vocab_size=256, num_heads=8, num_kv_heads=8,
                   head_dim=16, d_ff=256, num_experts=24, top_k=2,
                   moe_d_ff=256, dtype="float32", capacity_factor=100.0)
c6 = ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5))
c4 = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
LONG = 300                      # doomed sequences' output length (ticks the
                                # drain must wait out; migrate does not)

def build(cfg):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=512,
                        prefill_buckets=(32,), seed=0, kv_mode="paged",
                        kv_block_size=32, scaledown=MODE)
    srv.boot(cfg)
    return srv

def reqs():
    rng = np.random.default_rng(0)
    # rids 0-3: short fillers occupying the survivor slots, freeing them
    # before the scale command; rids 4-5: long outputs in the doomed slots
    outs = [8, 8, 12, 12, LONG, LONG]
    return [Request(i, 0.0, 24, o, prompt=rng.integers(0, 256, 24))
            for i, o in enumerate(outs)]

srv, rs = build(c6), reqs()
for r in rs:
    srv.submit(r)
t, n = 0.0, 0
while any(srv.requests[i].finish_s is None for i in range(4)):
    srv.tick(t); t += 0.1; n += 1      # fillers finish; 4,5 keep decoding
    assert n < 2000
assert all(srv.engine.slots[s].active for s in (4, 5))

t0 = time.perf_counter()
task = srv.start_scale(c4)
while not task.done:
    srv.tick(t); t += 0.1; n += 1
    task.advance(t)
    assert n < 20000
scale_wall = time.perf_counter() - t0

while any(r.finish_s is None for r in rs):
    srv.tick(t); t += 0.1; n += 1
    assert n < 20000
assert srv.hmm.active_cfg.ndev == 4
assert srv.hmm.kv_blocks.num_partitions == 2
srv.hmm.kv_blocks.check_invariants()
assert srv.engine.kv_stats()["used_blocks"] == 0

# unscaled reference at the TARGET config: bit-identical tokens expected
ref, rs2 = build(c4), reqs()
for r in rs2:
    ref.submit(r)
t2, n2 = 0.0, 0
while any(r.finish_s is None for r in rs2):
    ref.tick(t2); t2 += 0.1; n2 += 1
    assert n2 < 20000
for r in rs2:
    assert srv.engine.generated[r.rid] == ref.engine.generated[r.rid], r.rid

ev = srv.events[-1]
print("JSON:" + json.dumps(dict(
    mode=MODE, scale_wall_s=scale_wall,
    migrated_blocks=ev.migrated_blocks, migration_bytes=ev.migration_bytes,
    preemptions=srv.engine.preemptions,
    tokens={str(r.rid): srv.engine.generated[r.rid] for r in rs})))
"""


def _run_mode(mode: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", CODE, mode], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("JSON:")][0][5:])


def run_measured() -> Table:
    drain = _run_mode("drain")
    migrate = _run_mode("migrate")
    # acceptance: ≥5x faster release, identical tokens, real migration,
    # no recompute fallback needed
    assert migrate["scale_wall_s"] * 5 <= drain["scale_wall_s"], \
        (migrate["scale_wall_s"], drain["scale_wall_s"])
    assert migrate["tokens"] == drain["tokens"]
    assert migrate["migrated_blocks"] > 0 and drain["migrated_blocks"] == 0
    assert migrate["preemptions"] == 0

    t = Table("scaledown_measured",
              ["scaledown", "scale_wall_s", "migrated_blocks",
               "migration_bytes", "preemptions"])
    for row in (drain, migrate):
        t.add(row["mode"], row["scale_wall_s"], row["migrated_blocks"],
              row["migration_bytes"], row["preemptions"])
    return t


def main():
    t = run()
    t.show()
    for r in t.rows:
        ours = r[2]
        base = min(v for v in r[3:] if isinstance(v, float))
        print(f"  {r[0]} {r[1]}: {ours:.2f}s vs {base:.2f}s "
              f"({ours / base:.2f}x of fastest baseline)")
    m = run_measured()
    m.show()
    d, g = m.rows[0][1], m.rows[1][1]
    print(f"\nmeasured drain {d:.2f}s vs migrate {g:.2f}s "
          f"({d / g:.1f}x lower scale-down wall, bit-identical tokens)")


if __name__ == "__main__":
    main()
