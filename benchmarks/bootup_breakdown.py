"""Fig. 4a — instance initialization latency breakdown (cold boot)."""
from benchmarks.common import (PAPER_MODELS, TP_OF, Table, cfg_of,
                               tensors_for)
from repro.core.costmodel import DEFAULT_HW
from repro.core.scaling_plan import placement

BOOT_NDEV = {"deepseek-v2-lite-16b": 4, "qwen3-30b-a3b": 8, "deepseek-v3": 32}


def run() -> Table:
    hw = DEFAULT_HW
    t = Table("fig4a_bootup_breakdown_s",
              ["model", "ndev", "engine_boot", "weight_load_disk",
               "comm_init", "kv_alloc", "warmup", "total"])
    for model in PAPER_MODELS:
        tp = TP_OF[model]
        n = BOOT_NDEV[model]
        mcfg, tensors = tensors_for(model, tp)
        place = placement([x for x in tensors if x.kind != "kv"], cfg_of(n, tp))
        per_dev = max(sum(s.values()) for s in place.values())
        t_disk = per_dev / hw.disk_bw
        total = (hw.preinit_boot_s + t_disk + hw.comm_setup_s + hw.kv_alloc_s
                 + hw.warmup_s)
        t.add(model, n, hw.preinit_boot_s, t_disk, hw.comm_setup_s,
              hw.kv_alloc_s, hw.warmup_s, total)
    return t


def main():
    t = run()
    t.show()
    print("  (cold boot is dominated by engine boot + disk weight load — the "
          "two costs ElasticMoE's pre-init + zero-copy/P2P eliminate)")


if __name__ == "__main__":
    main()
