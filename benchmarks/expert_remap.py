"""Expert redistribution cost (beyond-paper CI smoke) — dense reshard vs
pooled vpage remap.

With the dense ``[E, D, F]`` expert banks, an EP change re-groups every bank
contiguously (``expert_owner`` placement): experts whose contiguous rank
changes cross devices even when their *current* device survives.  The
pooled weight store (``expert_mode="pooled"``, DESIGN.md §2) keeps experts
wherever they already are while balanced capacity allows and migrates only
the overflow/orphaned pages (``ExpertPageTable.stage_remap(min_move=True)``)
— commit is a table swap.

This module quantifies that gap with the real planner (byte-exact, the same
``plan_elastic`` / ``plan_elastic_paged`` pair the HMM's byte accounting is
asserted against in tests/test_pooled_experts.py) and projects wall-clock
with the calibrated cost model.  Columns:

* ``dense_MB`` / ``pooled_MB`` — total expert-weight P2P bytes,
* ``moved`` — migrated expert pages (pooled) vs expert P2P steps (dense),
* ``dense_s`` / ``pooled_s`` — projected scale time (all tensors, cost
  model bottleneck: max P2P bytes into one device),
* ``saved%`` — expert P2P byte reduction,
* ``int8_MB`` — pooled remap bytes with int8 expert pages
  (``expert_dtype="int8"``, DESIGN.md §11): the same page moves priced at
  one byte per element plus per-page f32 scales, i.e. ~half the bf16 bytes.
"""
from benchmarks.common import PAPER_MODELS, Table, scale_cost
from repro.core.scaling_plan import Op

TRANSITIONS = [(4, 6), (6, 8), (8, 6), (6, 4)]


def _expert_p2p(plan):
    steps = [s for s in plan.steps
             if s.op == Op.P2P and "/expert" in s.key.tensor]
    return sum(s.nbytes for s in steps), len(steps)


def run():
    t = Table("expert_remap_p2p",
              ["model", "transition", "dense_MB", "pooled_MB", "int8_MB",
               "moved", "dense_s", "pooled_s", "saved%"])
    for name in PAPER_MODELS:
        for n_old, n_new in TRANSITIONS:
            dense_plan, dense_cost = scale_cost(name, n_old, n_new,
                                                "elastic", paged=False)
            pooled_plan, pooled_cost = scale_cost(name, n_old, n_new,
                                                  "elastic", paged=True)
            quant_plan, _ = scale_cost(name, n_old, n_new, "elastic",
                                       paged=True, expert_dtype="int8")
            db, dn = _expert_p2p(dense_plan)
            pb, pn = _expert_p2p(pooled_plan)
            qb, qn = _expert_p2p(quant_plan)
            assert pb <= db, (name, n_old, n_new, pb, db)
            assert qn == pn, (name, n_old, n_new, qn, pn)
            # Same pages move; int8 pages are ~half the bf16 bytes
            # (one byte/element + f32 scale per bank).
            assert qb <= 0.55 * pb if pb else qb == 0, \
                (name, n_old, n_new, qb, pb)
            t.add(name, f"{n_old}->{n_new}", db / 1e6, pb / 1e6, qb / 1e6,
                  f"{pn}/{dn}", dense_cost.scale_time_s,
                  pooled_cost.scale_time_s,
                  100.0 * (1 - pb / db) if db else 0.0)
    return t
