"""KV pressure (beyond-paper) — dense vs paged admission under a burst.

Long-context serving (max_len 32k) on a fixed instance: dense admission
reserves a full-length KV row per request, so the HBM budget caps
concurrency at ``PerfModel.max_batch`` even though the workload's actual
sequences are ~4x shorter; block-occupancy admission
(``serving/kv_blocks.py``) admits by the tokens a request *currently*
holds, over-committing the pool and resolving overflow by preempting the
youngest request (recompute on resume).  The same burst that drowns the
dense queue completes under paged admission — with a nonzero preemption
count and near-full block-pool utilization at the peak.

Scaling is deliberately disabled (one fixed config) to isolate the
admission policy; the closed-loop driver sees the paged pressure signal via
``kv_stats`` on both backends (DESIGN.md §7).
"""
from benchmarks.common import Table
from repro.configs import get_config
from repro.serving.metrics import summarize
from repro.serving.simulator import PerfModel, ServingSimulator
from repro.serving.workload import burst, fixed_rate, make_workload

MODEL = "qwen3-30b-a3b"          # GQA: real (non-latent) KV, memory-bound
NDEV, TP = 2, 2
KV_SEQ_LEN = 32768               # dense reservation length
BLOCK = 512
UNTIL = 600.0

# ITL A/B (chunked prefill): long-context model, mixed prompt lengths
ITL_MODEL = "deepseek-v2-lite-16b"
ITL_KV_LEN = 16384
ITL_CHUNK, ITL_BUDGET = 1024, 1024
ITL_UNTIL = 400.0


def _workload(seed: int = 0):
    # prompts/outputs well under KV_SEQ_LEN: the dense reservation wastes
    # the difference, the paged pool serves it to other requests
    return make_workload(duration_s=90.0, rps_fn=burst(0.4, 8.0, 15.0, 40.0),
                         prompt_len=(2000, 8000), output_range=(500, 1500),
                         seed=seed)


def run_mode(kv_mode: str, seed: int = 0):
    mcfg = get_config(MODEL)
    perf = PerfModel(mcfg, kv_seq_len=KV_SEQ_LEN, kv_block_size=BLOCK,
                     max_batch_per_dev=48)
    sim = ServingSimulator(mcfg, tp=TP, ndev=NDEV, strategy="elastic",
                           perf=perf, kv_mode=kv_mode)
    reqs = _workload(seed)
    sim.run(reqs, until=0.0)
    peak_util, t = 0.0, 0.0
    while t < UNTIL and any(r.finish_s is None for r in reqs):
        t += 5.0
        sim.run([], until=t)
        peak_util = max(peak_util, sim.utilization())
    return reqs, sim, peak_util, t


def run() -> Table:
    t = Table("kv_pressure_dense_vs_paged",
              ["admission", "capacity", "finished", "makespan_s",
               "ttft_p50_s", "ttft_p99_s", "preemptions", "peak_util"])
    for mode in ("dense", "paged"):
        reqs, sim, peak_util, makespan = run_mode(mode)
        s = summarize(reqs, backend=sim)
        t.add(mode, sim.capacity(sim.current_config()), s["finished"],
              makespan, s["ttft_p50"], s["ttft_p99"],
              s.get("preemptions", 0), peak_util)
    return t


def run_quant_mode(kv_dtype, seed: int = 0):
    mcfg = get_config(MODEL)
    perf = PerfModel(mcfg, kv_seq_len=KV_SEQ_LEN, kv_block_size=BLOCK,
                     max_batch_per_dev=48, kv_dtype=kv_dtype)
    sim = ServingSimulator(mcfg, tp=TP, ndev=NDEV, strategy="elastic",
                           perf=perf, kv_mode="paged", kv_dtype=kv_dtype)
    reqs = _workload(seed)
    sim.run(reqs, until=0.0)
    peak_util, t = 0.0, 0.0
    while t < UNTIL and any(r.finish_s is None for r in reqs):
        t += 5.0
        sim.run([], until=t)
        peak_util = max(peak_util, sim.utilization())
    return reqs, sim, peak_util, t


def run_quant() -> Table:
    """Quantized KV pool (int8 + per-block scales, DESIGN.md §11) vs bf16.

    Same burst, same instance, paged admission in both arms; only the KV
    storage dtype changes.  Int8 halves the per-block bytes (plus small f32
    scale sidecars), so the same HBM budget carves ~2x the blocks —
    admission pressure drops (preemptions no worse, peak pool utilization
    lower) at unchanged request outcomes."""
    t = Table("quant_kv_pressure",
              ["kv_dtype", "pool_blocks", "block_KB", "finished",
               "makespan_s", "ttft_p99_s", "preemptions", "peak_util"])
    stats = {}
    for dtype in (None, "int8"):
        reqs, sim, peak_util, makespan = run_quant_mode(dtype)
        s = summarize(reqs, backend=sim)
        kv = sim.kv_stats()
        label = dtype or "bf16"
        stats[label] = (kv, s, peak_util)
        t.add(label, kv["num_blocks"], kv["block_bytes"] / 1024.0,
              s["finished"], makespan, s["ttft_p99"],
              s.get("preemptions", 0), peak_util)
    (kv_f, s_f, util_f), (kv_q, s_q, util_q) = stats["bf16"], stats["int8"]
    ratio = kv_q["num_blocks"] / kv_f["num_blocks"]
    assert ratio >= 1.8, ratio
    assert s_q["finished"] == s_f["finished"], (s_q, s_f)
    assert s_q.get("preemptions", 0) <= s_f.get("preemptions", 0)
    assert util_q <= util_f + 1e-9, (util_q, util_f)
    return t


def _longtail_prompt(rng):
    # long-tail mix: mostly short conversational prompts, with a 30% tail
    # of near-max-context (16k-token) dumps — under monolithic prefill
    # every long arrival stalls ALL running decodes for the full prompt's
    # forward pass; chunked prefill bounds the stall at one budget's worth
    return 16000 if rng.random() < 0.3 else int(rng.integers(200, 900))


def _itl_workload(seed: int = 0):
    return make_workload(duration_s=60.0, rps_fn=fixed_rate(2.0),
                         prompt_len=_longtail_prompt,
                         output_range=(60, 120), seed=seed)


def run_itl_mode(chunk: int, budget, seed: int = 0):
    mcfg = get_config(ITL_MODEL)
    perf = PerfModel(mcfg, kv_seq_len=ITL_KV_LEN, kv_block_size=BLOCK,
                     max_batch_per_dev=48)
    sim = ServingSimulator(mcfg, tp=TP, ndev=NDEV, strategy="elastic",
                           perf=perf, kv_mode="paged", prefill_chunk=chunk,
                           prefill_budget=budget)
    reqs = _itl_workload(seed)
    sim.run(reqs, until=0.0)
    t = 0.0
    while t < ITL_UNTIL and any(r.finish_s is None for r in reqs):
        t += 5.0
        sim.run([], until=t)
    return reqs, sim


def run_itl() -> Table:
    """Chunked-prefill ITL flatness under a long-prompt burst.

    Same long-tail workload on the same instance, monolithic
    (``prefill_chunk=0``, the arriving prompt's full forward pass stalls
    every running decode) vs chunked (``prefill_chunk>0``, at most
    ``prefill_budget`` prompt tokens ride along per decode tick).  The
    acceptance gate: chunked inter-token-latency p99 is strictly below
    monolithic — long prompts no longer show up in other requests' decode
    gaps (EXPERIMENTS.md)."""
    t = Table("chunked_prefill_itl",
              ["prefill", "finished", "ttft_p50_s", "itl_p50_s", "itl_p99_s"])
    stats = {}
    for label, chunk, budget in (("monolithic", 0, None),
                                 ("chunked", ITL_CHUNK, ITL_BUDGET)):
        reqs, sim = run_itl_mode(chunk, budget)
        s = summarize(reqs, backend=sim)
        stats[label] = s
        t.add(label, s["finished"], s["ttft_p50"], s["itl_p50"],
              s["itl_p99"])
    assert stats["chunked"]["finished"] == stats["monolithic"]["finished"]
    assert stats["chunked"]["itl_p99"] < stats["monolithic"]["itl_p99"], \
        (stats["chunked"]["itl_p99"], stats["monolithic"]["itl_p99"])
    return t


if __name__ == "__main__":
    run().show()
    run_quant().show()
    run_itl().show()
