"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` runs everything and prints both human-readable
tables and a machine-readable CSV block (name,<row...>).
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (ablation, bootup_breakdown, engine_measured,
                            granularity, latency_breakdown, memory_vs_ep,
                            peak_memory, scaledown_latency, scaleup_latency,
                            slo_compliance, slo_dynamics, throughput_windows)
    modules = [
        ("fig1", granularity),
        ("fig4a", bootup_breakdown),
        ("fig4b", memory_vs_ep),
        ("fig7", scaleup_latency),
        ("fig8", peak_memory),
        ("fig9", slo_dynamics),
        ("fig10", slo_compliance),
        ("fig11", latency_breakdown),
        ("fig12", scaledown_latency),
        ("table1+3", ablation),
        ("table2", throughput_windows),
        ("measured", engine_measured),
    ]
    tables = []
    failures = []
    for name, mod in modules:
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n[{name}] {mod.__doc__.splitlines()[0]}")
        try:
            if mod is slo_dynamics:
                outs = [mod.run(True), mod.run(False)]
            else:
                out = mod.run()
                outs = out if isinstance(out, list) else [out]
            for t in outs:
                if t is not None:
                    t.show()
                    tables.append(t)
            print(f"[{name}] done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:
            traceback.print_exc()
            failures.append((name, str(e)))
    print(f"\n{'=' * 72}\n# CSV")
    print("table,row...")
    for t in tables:
        for line in t.csv_rows():
            print(line)
    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED: {failures}")
        raise SystemExit(1)
    print(f"\nall {len(modules)} benchmarks passed "
          f"({len(tables)} tables)")


if __name__ == "__main__":
    main()
