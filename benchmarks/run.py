"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything and prints both
human-readable tables and a machine-readable CSV block (name,<row...>).
``--json PATH`` additionally writes every table to one JSON document — the
schema is documented in benchmarks/README.md:

    {"tables": [{"name": str, "cols": [str], "rows": [[cell, ...]]}],
     "failures": [[benchmark_name, error_str]]}
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write all tables as one JSON document")
    ap.add_argument("--only", metavar="NAME", default=None,
                    help="run a single benchmark by name (e.g. fig9, table2)")
    args = ap.parse_args()

    from benchmarks import (ablation, bootup_breakdown, engine_measured,
                            expert_remap, expert_skew, fleet, granularity,
                            kv_pressure, latency_breakdown, memory_vs_ep,
                            overlap, peak_memory, scaledown_latency,
                            scaleup_latency, slo_compliance, slo_dynamics,
                            throughput_windows, trace_overhead)
    modules = [
        ("fig1", granularity),
        ("fig4a", bootup_breakdown),
        ("fig4b", memory_vs_ep),
        ("fig7", scaleup_latency),
        ("fig8", peak_memory),
        ("fig9", slo_dynamics),
        ("fig10", slo_compliance),
        ("fig11", latency_breakdown),
        ("fig12", scaledown_latency),
        ("table1+3", ablation),
        ("table2", throughput_windows),
        ("kv_pressure", kv_pressure),
        # chunked-prefill ITL flatness A/B (same module, own entry so CI
        # can smoke it via --only without the slower admission sweep)
        ("chunked_itl", kv_pressure),
        # int8 KV pool vs bf16 under the same burst (same module, own
        # entry: pool capacity ~2x at halved block bytes, DESIGN.md §11)
        ("quant_kv", kv_pressure),
        ("expert_remap", expert_remap),
        # skew-aware rebalancing A/B: Zipf routing, replicate-hot /
        # demote-cold mid-serving, scale-event pricing with the cold tier
        ("expert_skew", expert_skew),
        ("overlap", overlap),
        # measured drain-vs-migrate scale-down on the real engine (the
        # fig12 entry above is the cost-model projection)
        ("scaledown_migrate", scaledown_latency),
        ("measured", engine_measured),
        # tracing disabled-vs-enabled throughput A/B + trace artifact
        ("trace_overhead", trace_overhead),
        # shared-pool fleet vs static per-model pools A/B with
        # scale-to-zero (park/unpark) on anti-correlated diurnal demand
        ("fleet", fleet),
    ]
    if args.only:
        modules = [(n, m) for n, m in modules if n == args.only]
        if not modules:
            raise SystemExit(f"unknown benchmark {args.only!r}")
    tables = []
    failures = []
    for name, mod in modules:
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n[{name}] {mod.__doc__.splitlines()[0]}")
        try:
            if mod is slo_dynamics:
                outs = [mod.run(True), mod.run(False), mod.run_closed_loop()]
            elif name == "scaledown_migrate":
                outs = [mod.run_measured()]
            elif name == "chunked_itl":
                outs = [mod.run_itl()]
            elif name == "quant_kv":
                outs = [mod.run_quant()]
            else:
                out = mod.run()
                outs = out if isinstance(out, list) else [out]
            for t in outs:
                if t is not None:
                    t.show()
                    tables.append(t)
            print(f"[{name}] done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:
            traceback.print_exc()
            failures.append((name, str(e)))
    print(f"\n{'=' * 72}\n# CSV")
    print("table,row...")
    for t in tables:
        for line in t.csv_rows():
            print(line)
    if args.json:
        doc = {"tables": [{"name": t.name, "cols": t.cols, "rows": t.rows}
                          for t in tables],
               "failures": [list(f) for f in failures]}
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, default=str)
        print(f"\nwrote {len(tables)} tables -> {args.json}")
    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED: {failures}")
        raise SystemExit(1)
    print(f"\nall {len(modules)} benchmarks passed "
          f"({len(tables)} tables)")


if __name__ == "__main__":
    main()
