"""Skew-aware expert rebalancing A/B (beyond-paper CI smoke, DESIGN.md §10).

Zipf-routed pooled workload, same model / devices / arrivals, two arms:

* **unbalanced** — pooled expert store, no rebalancer: every expert serves
  from its primary page, the hottest rank carries the full Zipf head;
* **rebalanced** — the shared :class:`RebalancePolicy` replicates hot
  experts onto the least-loaded ranks and demotes cold experts into the
  pinned-host tier mid-serving.

Reported per model (``expert_skew_balance``): rebalance passes, pages
replicated/demoted, and the layer-averaged **max per-rank routed share**
(``serving.rebalance.max_rank_load`` — 1/ndev is perfect balance) under the
primary-only vs the replica-aware serving assignment on the *same*
synthesized histogram.  The rebalanced arm must never be worse.

``expert_skew_scale`` then prices the next scale event with the real
planner (byte-exact ``plan_elastic_paged``) from each arm's live table:
with the cold tier populated, demoted movers stream H2D — the cold arm's
expert-P2P bytes drop and the freed interconnect shows up as ``host_MB``
(the ``host`` cost-model bucket), with the pinned tier's footprint in
``tier_MB``.
"""
from benchmarks.common import TP_OF, Table, cfg_of
from repro.configs import get_config
from repro.core.costmodel import plan_cost
from repro.core.expert_pages import pooled_layout
from repro.core.scaling_plan import Op, plan_elastic_paged
from repro.core.topology import model_tensors
from repro.serving.rebalance import RebalancePolicy, max_rank_load
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import make_workload

# the two small paper MoEs: deepseek-v3's 61x256 table adds nothing to the
# A/B beyond wall-clock
MODELS = ["deepseek-v2-lite-16b", "qwen3-30b-a3b"]
NDEV = 6
SKEW = 1.2
TRANSITION = (6, 8)


def _arm(name: str, rebalance: bool) -> ServingSimulator:
    mcfg = get_config(name)
    pol = RebalancePolicy(min_samples=2, cooldown_s=1.0,
                          max_actions=32) if rebalance else None
    sim = ServingSimulator(mcfg, tp=TP_OF.get(name, 2), ndev=NDEV,
                           expert_mode="pooled", rebalance=pol,
                           routing_skew=SKEW)
    reqs = make_workload(duration_s=8.0, rps_fn=lambda t: 4.0,
                         prompt_len=256, output_range=(64, 64), seed=0)
    sim.run(reqs, until=12.0)
    return sim


def _expert_bytes(plan, op: Op) -> int:
    return sum(s.nbytes for s in plan.steps
               if s.op == op and "/expert" in s.key.tensor)


def run():
    bal = Table("expert_skew_balance",
                ["model", "passes", "replicated", "demoted",
                 "max_load_unbal", "max_load_rebal", "improve%"])
    sca = Table("expert_skew_scale",
                ["model", "transition", "warm_p2p_MB", "int8_p2p_MB",
                 "cold_p2p_MB", "host_MB", "host_s", "tier_MB"])
    for name in MODELS:
        mcfg = get_config(name)
        tp = TP_OF.get(name, 2)
        unbal = _arm(name, rebalance=False)
        rebal = _arm(name, rebalance=True)
        summ = rebal.rebalance_summary()
        assert summ is not None and summ["replicated"] >= 1 \
            and summ["demoted"] >= 1, summ

        # balance metric on the shared Zipf shares: primary-only assignment
        # (the unbalanced arm's layout) vs the replica-aware least-loaded
        # assignment over the rebalanced arm's copies
        share = rebal.routing._share
        L = mcfg.num_layers - mcfg.first_k_dense
        cfg = rebal.current_config()
        before = pooled_layout(unbal.expert_pages.active, cfg, L,
                               mcfg.num_experts, 2 * L * mcfg.num_experts)
        after = pooled_layout(rebal.expert_pages.active, cfg, L,
                              mcfg.num_experts, 2 * L * mcfg.num_experts,
                              replicas=rebal.expert_pages.replicas,
                              load=share, slots_per_rank=rebal._elm())
        m0 = max_rank_load(share, before["edest"], cfg.ndev)
        m1 = max_rank_load(share, after["edest"], cfg.ndev)
        assert m1 <= m0, (name, m0, m1)
        bal.add(name, summ["passes"], summ["replicated"], summ["demoted"],
                m0, m1, 100.0 * (1 - m1 / m0) if m0 else 0.0)

        # scale-event pricing from each arm's LIVE table (clones: don't
        # disturb the sims).  The cold tier turns demoted movers' P2P into
        # H2D — byte-exact planner, calibrated cost model.
        n_old, n_new = TRANSITION
        old, new = cfg_of(n_old, tp), cfg_of(n_new, tp)
        tensors = model_tensors(mcfg, tp)
        warm_plan = plan_elastic_paged(tensors, old, new,
                                       unbal.expert_pages.clone(),
                                       first_k_dense=mcfg.first_k_dense)
        cold_table = rebal.expert_pages.clone()
        assert cold_table.host, "rebalanced arm must have a cold tier"
        cold_plan = plan_elastic_paged(tensors, old, new, cold_table,
                                       first_k_dense=mcfg.first_k_dense)
        # quantized arm: the same moves priced at int8 expert pages
        # (expert_dtype="int8", DESIGN.md §11) — ~half the warm-arm bytes
        quant_plan = plan_elastic_paged(
            model_tensors(mcfg, tp, expert_dtype="int8"), old, new,
            unbal.expert_pages.clone(), first_k_dense=mcfg.first_k_dense)
        warm_p2p = _expert_bytes(warm_plan, Op.P2P)
        quant_p2p = _expert_bytes(quant_plan, Op.P2P)
        cold_p2p = _expert_bytes(cold_plan, Op.P2P)
        cold_host = _expert_bytes(cold_plan, Op.HOST)
        assert cold_p2p + cold_host > 0 and cold_p2p <= warm_p2p + cold_host
        assert quant_p2p <= 0.55 * warm_p2p if warm_p2p else quant_p2p == 0
        sca.add(name, f"{n_old}->{n_new}", warm_p2p / 1e6, quant_p2p / 1e6,
                cold_p2p / 1e6, cold_host / 1e6,
                plan_cost(cold_plan).breakdown["host"],
                summ["host_tier_bytes"] / 1e6)
    return [bal, sca]


if __name__ == "__main__":
    for t in run():
        t.show()
