#!/usr/bin/env python
"""Docs lint: every ``*.md`` file referenced from Python source must exist.

Docstrings across the repo cite documentation files (e.g. "DESIGN.md §2",
"EXPERIMENTS.md §Perf B", "benchmarks/README.md"); a citation to a missing
file is a broken promise to the reader.  CI runs this script and fails on
any dangling reference.

Usage:  python tools/check_doc_refs.py [repo_root]
Exit status: 0 clean, 1 dangling references (listed on stderr).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# a markdown-file token: path-ish characters ending in ".md" (word boundary
# keeps ".mdx" etc. out); leading "./" is tolerated.
MD_REF = re.compile(r"(?<![\w./-])\.?/?([A-Za-z0-9_][A-Za-z0-9_/.-]*\.md)\b")

SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def dangling_refs(root: Path):
    missing = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            text = py.read_text(encoding="utf-8", errors="replace")
            for lineno, line in enumerate(text.splitlines(), 1):
                for m in MD_REF.finditer(line):
                    rel = m.group(1)
                    # resolve against repo root, then the citing file's dir
                    if (root / rel).is_file() \
                            or (py.parent / rel).is_file():
                        continue
                    missing.append((py.relative_to(root), lineno, rel))
    return missing


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent
    missing = dangling_refs(root)
    if missing:
        print("dangling .md references:", file=sys.stderr)
        for path, lineno, ref in missing:
            print(f"  {path}:{lineno}: {ref}", file=sys.stderr)
        return 1
    print("doc references OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
