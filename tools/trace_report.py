#!/usr/bin/env python
"""Render a Chrome-trace JSON (exported by repro.obs) as text reports.

Usage:  python tools/trace_report.py trace.json [--cat CAT] [--timeline N]

Three sections:

* **summary** — per (cat, name) over complete ("X") spans: count, total /
  mean / max duration in ms, sorted by total time descending;
* **phase timeline** — scale-phase spans (cat ``scale``) and HMM staging
  spans in start order with text bars, the at-a-glance view of the
  STAGING ∥ COMPILING ∥ MIGRATING concurrency claim;
* **overlap** — how many ``transfer`` spans overlapped a ``decode.tick``
  span in wall-clock (the paper's serving-while-staging evidence).

Stdlib only; works on traces from the real engine (perf_counter domain)
and the simulator (sim-time domain) alike.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

BAR_WIDTH = 48


def _spans(doc, cat=None):
    for rec in doc.get("traceEvents", []):
        if rec.get("ph") != "X":
            continue
        if cat is not None and rec.get("cat") != cat:
            continue
        yield rec


def summary_rows(doc, cat=None):
    agg = defaultdict(lambda: [0, 0.0, 0.0])  # count, total_us, max_us
    for rec in _spans(doc, cat):
        key = (rec.get("cat", ""), rec["name"])
        a = agg[key]
        a[0] += 1
        a[1] += rec["dur"]
        a[2] = max(a[2], rec["dur"])
    rows = [(c, n, cnt, tot / 1e3, tot / cnt / 1e3, mx / 1e3)
            for (c, n), (cnt, tot, mx) in agg.items()]
    rows.sort(key=lambda r: -r[3])
    return rows


def print_summary(doc, cat=None, file=sys.stdout):
    rows = summary_rows(doc, cat)
    print("\n## span summary", file=file)
    hdr = ("cat", "name", "count", "total_ms", "mean_ms", "max_ms")
    fmt = [str, str, str,
           lambda v: f"{v:.2f}", lambda v: f"{v:.3f}", lambda v: f"{v:.3f}"]
    cells = [hdr] + [tuple(f(v) for f, v in zip(fmt, r)) for r in rows]
    widths = [max(len(c[i]) for c in cells) for i in range(len(hdr))]
    for c in cells:
        print("  ".join(v.ljust(w) for v, w in zip(c, widths)), file=file)
    return rows


def print_timeline(doc, max_rows=40, file=sys.stdout):
    spans = sorted((r for r in _spans(doc)
                    if r.get("cat") in ("scale", "hmm")),
                   key=lambda r: r["ts"])[:max_rows]
    print("\n## phase timeline (scale + hmm spans)", file=file)
    if not spans:
        print("(no scale/hmm spans in trace)", file=file)
        return
    t0 = min(r["ts"] for r in spans)
    t1 = max(r["ts"] + r["dur"] for r in spans)
    scale = BAR_WIDTH / max(t1 - t0, 1e-9)
    for r in spans:
        a = int((r["ts"] - t0) * scale)
        b = max(int((r["ts"] + r["dur"] - t0) * scale), a + 1)
        bar = " " * a + "#" * (b - a)
        print(f"{r['name']:<22} {bar:<{BAR_WIDTH}} "
              f"[{(r['ts'] - t0) / 1e3:9.2f}ms +{r['dur'] / 1e3:8.2f}ms]",
              file=file)


def overlap_report(doc):
    """(n_transfer, n_overlapping, decode_ticks) — a transfer span counts
    as overlapping when any decode.tick span intersects it in time."""
    transfers = list(_spans(doc, "transfer"))
    ticks = [r for r in _spans(doc, "serve") if r["name"] == "decode.tick"]
    n_overlap = 0
    for tr in transfers:
        a0, a1 = tr["ts"], tr["ts"] + tr["dur"]
        if any(t["ts"] < a1 and a0 < t["ts"] + t["dur"] for t in ticks):
            n_overlap += 1
    return len(transfers), n_overlap, len(ticks)


def print_overlap(doc, file=sys.stdout):
    n_tr, n_ov, n_ticks = overlap_report(doc)
    print("\n## staging/serving overlap", file=file)
    print(f"transfer spans: {n_tr}  decode ticks: {n_ticks}  "
          f"transfer spans overlapping a decode tick: {n_ov}", file=file)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON from repro.obs")
    ap.add_argument("--cat", default=None,
                    help="restrict the summary to one category")
    ap.add_argument("--timeline", type=int, default=40, metavar="N",
                    help="max spans in the phase timeline (default 40)")
    args = ap.parse_args(argv)
    with open(args.trace) as fh:
        doc = json.load(fh)
    n = len(doc.get("traceEvents", []))
    print(f"# trace report: {args.trace} ({n} events)")
    print_summary(doc, args.cat)
    print_timeline(doc, args.timeline)
    print_overlap(doc)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
