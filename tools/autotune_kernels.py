#!/usr/bin/env python
"""Autotune harness for the paged Pallas kernels, pinned to the roofline.

Sweeps the static block/grid knobs of the serving kernels — decode-attention
``block_k``, paged-GMM / expert-FFN ``block_c``/``block_f`` — times each
candidate, and compares achieved HBM throughput against the memory-bound
bound from ``analysis/roofline.py`` (bytes-touched / HBM_BW).  The
block-table and mixed prefill+decode kernels have no free knobs (their block
size IS the pool layout's ``bs``), so they are timed and reported against
the roofline without a sweep.  With ``--quant`` the int8 variants run at the
winning f32 knobs and report their (roughly halved) byte traffic.

The winners are persisted as a JSON table (default
``tools/autotune_best.json``) that ``repro.analysis.autotune`` loads and
``repro.kernels.ops`` consults at dispatch time for any block-size kwarg the
caller leaves unset — a one-off offline sweep feeds the serving hot path
with no runtime tuning machinery.

On the CPU container the kernels execute in Pallas interpret mode, so
timings rank Python emulation, not Mosaic code — useful as a dry run of the
sweep mechanics (CI runs ``--trials 2`` and asserts the table parses), not
as tuning data.  Run on a real TPU with ``REPRO_PALLAS_INTERPRET=0`` for
meaningful numbers.

Usage:
  python tools/autotune_kernels.py --trials 5 --out tools/autotune_best.json
  python tools/autotune_kernels.py --kernels paged_gmm --quant --trials 2
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.analysis.autotune import TUNABLE_KEYS, load_best_configs  # noqa: E402
from repro.analysis.roofline import HBM_BW                  # noqa: E402
from repro.kernels import ops                               # noqa: E402
from repro.kernels.quant import quantize_rows               # noqa: E402

RNG = np.random.default_rng(7)


def _time_call(fn, trials: int) -> float:
    jax.block_until_ready(fn())          # compile + warmup, untimed
    best = math.inf
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _f32(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# --------------------------------------------------------------- kernel rigs
# Each rig returns (candidates, make_fn(knobs), bytes_f32, quant_entry|None)
# where bytes_f32 is the kernel's minimum HBM traffic (inputs read once +
# outputs written once) — the roofline memory-bound numerator.

def rig_paged_decode(a):
    B, QH, KVH, hd, S = a.batch, a.q_heads, a.kv_heads, a.head_dim, a.seq_len
    q = _f32(B, QH, hd)
    k = _f32(B, S, KVH, hd)
    v = _f32(B, S, KVH, hd)
    lengths = jnp.full((B,), S, jnp.int32)
    nbytes = (q.nbytes + k.nbytes + v.nbytes) + q.nbytes   # out == q shape
    cands = [{"block_k": bk} for bk in (64, 128, 256, 512)
             if bk <= S and S % bk == 0]

    def make(knobs):
        return lambda: ops.paged_decode_attention(q, k, v, lengths, **knobs)

    return cands, make, nbytes, None


def rig_block_paged(a):
    B, QH, KVH, hd = a.batch, a.q_heads, a.kv_heads, a.head_dim
    bs, MB = a.kv_block_size, a.seq_len // a.kv_block_size
    NB = B * MB
    kp = _f32(NB, bs, KVH, hd)
    vp = _f32(NB, bs, KVH, hd)
    q = _f32(B, QH, hd)
    bt = jnp.asarray(RNG.permutation(NB).reshape(B, MB), jnp.int32)
    lengths = jnp.full((B,), a.seq_len, jnp.int32)
    nbytes = q.nbytes * 2 + kp.nbytes + vp.nbytes

    def make(knobs):
        return lambda: ops.block_paged_decode_attention(
            q, kp, vp, bt, lengths, impl="kernel", **knobs)

    def quant():
        kq, ks = quantize_rows(kp, (-2, -1))
        vq, vs = quantize_rows(vp, (-2, -1))
        qb = (q.nbytes * 2 + kq.nbytes + vq.nbytes
              + ks.nbytes + vs.nbytes)
        return (lambda: ops.quant_block_paged_decode_attention(
            q, kq, ks, vq, vs, bt, lengths, impl="kernel")), qb

    return [{}], make, nbytes, quant


def rig_mixed(a):
    B, QH, KVH, hd = a.batch, a.q_heads, a.kv_heads, a.head_dim
    bs, MB = a.kv_block_size, a.seq_len // a.kv_block_size
    NB, G = B * MB, a.chunk
    kp = _f32(NB, bs, KVH, hd)
    vp = _f32(NB, bs, KVH, hd)
    q = _f32(B, G, QH, hd)
    bt = jnp.asarray(RNG.permutation(NB).reshape(B, MB), jnp.int32)
    ctx = jnp.full((B,), a.seq_len, jnp.int32)
    qlen = jnp.full((B,), G, jnp.int32)
    nbytes = q.nbytes * 2 + kp.nbytes + vp.nbytes

    def make(knobs):
        return lambda: ops.mixed_block_paged_attention(
            q, kp, vp, bt, ctx, qlen, impl="kernel", **knobs)

    def quant():
        kq, ks = quantize_rows(kp, (-2, -1))
        vq, vs = quantize_rows(vp, (-2, -1))
        qb = (q.nbytes * 2 + kq.nbytes + vq.nbytes
              + ks.nbytes + vs.nbytes)
        return (lambda: ops.quant_mixed_block_paged_attention(
            q, kq, ks, vq, vs, bt, ctx, qlen, impl="kernel")), qb

    return [{}], make, nbytes, quant


def _gmm_cands(C, F):
    out = []
    for bc in (64, 128, 256):
        if bc > C or C % bc:
            continue
        for bf in (128, 256):
            if bf > F or F % bf:
                continue
            out.append({"block_c": bc, "block_f": bf})
    return out or [{"block_c": min(128, C), "block_f": min(128, F)}]


def rig_paged_gmm(a):
    E, C, D, F = a.experts, a.tokens, a.d_model, a.d_ff
    pool = _f32(a.pool_pages, D, F)
    x = _f32(E, C, D)
    table = jnp.asarray(RNG.choice(a.pool_pages, E, replace=False), jnp.int32)
    nbytes = x.nbytes + E * D * F * 4 + E * C * F * 4

    def make(knobs):
        return lambda: ops.paged_gmm(table, pool, x, **knobs)

    def quant():
        pq, ps = quantize_rows(pool, (-2, -1))
        qb = x.nbytes + E * (D * F + 4) + E * C * F * 4
        return (lambda: ops.quant_paged_gmm(table, pq, ps, x,
                                            impl="kernel")), qb

    return _gmm_cands(C, F), make, nbytes, quant


def rig_paged_ffn(a):
    E, C, D, F = a.experts, a.tokens, a.d_model, a.d_ff
    pi, pg = _f32(a.pool_pages, D, F), _f32(a.pool_pages, D, F)
    po = _f32(a.pool_pages, F, D)
    x = _f32(E, C, D)
    table = jnp.asarray(RNG.choice(a.pool_pages, E, replace=False), jnp.int32)
    # 2 up-GMMs + silu-gate elementwise + down-GMM, each read-once/write-once
    nbytes = (x.nbytes * 2 + 2 * E * (D * F * 4 + C * F * 4)   # wi, wg
              + 3 * E * C * F * 4                               # h*silu(g)
              + E * (F * D * 4 + C * F * 4) + E * C * D * 4)    # wo

    def make(knobs):
        return lambda: ops.paged_expert_ffn(table, table, table,
                                            pi, pg, po, x,
                                            impl="kernel", **knobs)

    def quant():
        qi, si = quantize_rows(pi, (-2, -1))
        qg, sg = quantize_rows(pg, (-2, -1))
        qo, so = quantize_rows(po, (-2, -1))
        qb = (x.nbytes * 2 + 2 * E * (D * F + 4 + C * F * 4)
              + 3 * E * C * F * 4
              + E * (F * D + 4 + C * F * 4) + E * C * D * 4)
        return (lambda: ops.quant_paged_expert_ffn(
            table, table, table, qi, qg, qo, si, sg, so, x,
            impl="kernel")), qb

    return _gmm_cands(C, F), make, nbytes, quant


RIGS = {
    "paged_decode_attention": rig_paged_decode,
    "block_paged_decode_attention": rig_block_paged,
    "mixed_block_paged_attention": rig_mixed,
    "paged_gmm": rig_paged_gmm,
    "paged_expert_ffn": rig_paged_ffn,
}


def sweep_kernel(name, a) -> dict:
    cands, make, nbytes, quant = RIGS[name](a)
    t_roof = nbytes / HBM_BW
    rows = []
    for knobs in cands:
        el = _time_call(make(knobs), a.trials)
        rows.append({**knobs, "elapsed_s": el,
                     "achieved_gbps": nbytes / el / 1e9,
                     "frac_of_roofline": t_roof / el})
    rows.sort(key=lambda r: r["elapsed_s"])
    entry = {"bytes": nbytes, "t_roofline_s": t_roof,
             "candidates": rows, "best": rows[0]}
    if a.quant and quant is not None:
        qfn, qbytes = quant()
        el = _time_call(qfn, a.trials)
        entry["quant_int8"] = {
            "bytes": qbytes, "t_roofline_s": qbytes / HBM_BW,
            "elapsed_s": el, "achieved_gbps": qbytes / el / 1e9,
            "frac_of_roofline": qbytes / HBM_BW / el,
            "bytes_vs_f32": qbytes / nbytes}
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trials", type=int, default=5,
                    help="timed repetitions per candidate (best-of)")
    ap.add_argument("--out", type=Path,
                    default=Path(__file__).resolve().parent
                    / "autotune_best.json")
    ap.add_argument("--kernels", nargs="*", default=sorted(RIGS),
                    choices=sorted(RIGS), metavar="KERNEL")
    ap.add_argument("--quant", action="store_true",
                    help="also time the int8 variants at the winning knobs")
    # sweep shapes (defaults sized for a quick interpret-mode dry run)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--q-heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8,
                    help="mixed kernel prefill-chunk length")
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=128,
                    help="tokens per local expert (GMM C dim)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--pool-pages", type=int, default=8)
    a = ap.parse_args(argv)

    report = {"meta": {"backend": jax.default_backend(),
                       "interpret": ops._INTERPRET,
                       "trials": a.trials, "hbm_bw": HBM_BW,
                       "shapes": {k: v for k, v in vars(a).items()
                                  if isinstance(v, int)}},
              "kernels": {}}
    for name in a.kernels:
        print(f"== {name}")
        entry = sweep_kernel(name, a)
        report["kernels"][name] = entry
        for r in entry["candidates"]:
            knobs = {k: v for k, v in r.items()
                     if k in TUNABLE_KEYS.get(name, ())}
            mark = " *" if r is entry["best"] else ""
            print(f"   {json.dumps(knobs):24s} {r['elapsed_s'] * 1e3:9.3f} ms"
                  f"  {r['achieved_gbps']:8.3f} GB/s"
                  f"  {r['frac_of_roofline'] * 100:6.2f}% of roofline{mark}")
        q = entry.get("quant_int8")
        if q:
            print(f"   int8 @ best knobs        {q['elapsed_s'] * 1e3:9.3f} ms"
                  f"  {q['achieved_gbps']:8.3f} GB/s"
                  f"  ({q['bytes_vs_f32'] * 100:.1f}% of f32 bytes)")

    a.out.parent.mkdir(parents=True, exist_ok=True)
    a.out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwrote {a.out}")

    # round-trip through the dispatch-side loader: the persisted table must
    # parse and expose knobs for every tunable kernel that was swept
    table = load_best_configs(a.out, refresh=True)
    tuned = [k for k in a.kernels if TUNABLE_KEYS.get(k)]
    missing = [k for k in tuned if k not in table]
    print(f"dispatch table: {json.dumps(table)}")
    if missing:
        print(f"ERROR: no tunable knobs parsed for {missing}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
